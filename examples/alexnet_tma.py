"""AlexNet on the TMA accelerator: functional PSI inference + the cycle/
energy model — reproduces the paper's headline numbers end to end.

  PYTHONPATH=src python examples/alexnet_tma.py
"""
import dataclasses

import jax
import jax.numpy as jnp

from repro.core import baselines as bl, tma_model as tm
from repro.models import cnn


def main():
    # 1. functional: AlexNet forward with PSI-INT5 weights (bit-faithful to
    #    what the SAM array computes)
    params = cnn.init_cnn(cnn.ALEXNET, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 227, 227, 3))
    y32 = cnn.cnn_forward(params, x, cnn.ALEXNET)
    qp = cnn.quantize_cnn(params, 5)
    y5 = cnn.cnn_forward(qp, x, dataclasses.replace(cnn.ALEXNET,
                                                    quant_mode="psi5"))
    rel = float(jnp.linalg.norm(y5 - y32) / jnp.linalg.norm(y32))
    print(f"AlexNet logits: PSI-INT5 vs FP32 relative error {rel:.4f}")

    # 2. performance: what the 4x4x16 NE array does with this network
    layers = tm.alexnet_layers()
    for bits in (5, 8):
        fps = tm.frame_rate(layers, bits)
        e = tm.energy_per_frame_j(layers, bits)
        print(f"TMA INT{bits}: {fps:5.1f} fps @200 MHz, "
              f"{e * 1e3:.2f} mJ/frame @250 MHz/1.0 V, "
              f"{tm.macs_per_watt(bits) / 1e12:.2f} TMACs/W")
    ey = sum(bl.EYERISS.layer_time_s(l) for l in layers[:5])
    t5 = sum(r.time_s for r in tm.analyze_network(layers[:5], 5))
    print(f"conv1-5 vs Eyeriss: {ey / t5:.1f}x faster (INT5)")


if __name__ == "__main__":
    main()
