"""Table I experiment end-to-end: LeNet-5 on procedural MNIST digits,
FP32 vs QAT vs post-training PSI quantization.

Paper claim: LeNet-5 Top-1 degradation is 0 % at both INT5 and INT8.

  PYTHONPATH=src python examples/train_lenet_qat.py
"""
import dataclasses

import jax
import jax.numpy as jnp

from repro.data.pipeline import synthetic_mnist
from repro.models import cnn


def train(cfg, steps=300, lr=0.05, seed=0):
    params = cnn.init_cnn(cnn.LENET5, jax.random.PRNGKey(seed))
    xs, ys = synthetic_mnist(4096, seed=1)

    @jax.jit
    def step(p, batch):
        loss, grads = jax.value_and_grad(
            lambda pp: cnn.cnn_loss(pp, batch, cfg)[0])(p)
        return loss, jax.tree_util.tree_map(lambda a, g: a - lr * g, p, grads)

    bs = 128
    for i in range(steps):
        lo = (i * bs) % (len(xs) - bs)
        batch = {"images": jnp.asarray(xs[lo:lo + bs]),
                 "labels": jnp.asarray(ys[lo:lo + bs])}
        loss, params = step(params, batch)
    return params


def evaluate(params, cfg):
    xt, yt = synthetic_mnist(2048, seed=2)
    _, m = cnn.cnn_loss(params, {"images": jnp.asarray(xt),
                                 "labels": jnp.asarray(yt)}, cfg)
    return float(m["acc"])


def main():
    fp32 = cnn.LENET5
    params = train(fp32)
    acc32 = evaluate(params, fp32)
    print(f"FP32 test accuracy: {acc32:.4f}")
    for bits in (8, 5):
        # post-training quantization (what the deployed accelerator runs)
        qp = cnn.quantize_cnn(params, bits)
        qcfg = dataclasses.replace(fp32, quant_mode=f"psi{bits}")
        acc_ptq = evaluate(qp, qcfg)
        # QAT (the paper trains WITH the quantization)
        qat_cfg = dataclasses.replace(fp32, quant_mode=f"qat{bits}")
        qat_params = train(qat_cfg)
        acc_qat = evaluate(cnn.quantize_cnn(qat_params, bits), qcfg)
        print(f"PSI-INT{bits}: PTQ {acc_ptq:.4f} "
              f"({100*(acc32-acc_ptq):+.2f}pp)  "
              f"QAT {acc_qat:.4f} ({100*(acc32-acc_qat):+.2f}pp)   "
              f"[paper: 0.0pp]")


if __name__ == "__main__":
    main()
