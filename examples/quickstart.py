"""Quickstart: train a reduced qwen3-style LM with PSI-INT8 QAT, quantize to
the serving format, and generate tokens — the full paper-technique lifecycle
in one script.

  PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced_config
from repro.data.pipeline import TokenStream
from repro.models import build_model
from repro.optim import adamw, cosine_schedule


def main():
    cfg = reduced_config(get_config("qwen3-8b"), quant_mode="qat8")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = adamw(lr=cosine_schedule(3e-3, 10, 200))
    opt_state = opt.init(params)
    stream = TokenStream(cfg.vocab_size, seq_len=64, global_batch=16)

    @jax.jit
    def train_step(params, opt_state, tokens):
        (loss, _), grads = jax.value_and_grad(
            lambda p: model.loss(p, {"tokens": tokens}), has_aux=True)(params)
        params, opt_state, _ = opt.update(grads, opt_state, params)
        return params, opt_state, loss

    print("== training (QAT-INT8: the paper's 'trained with the proposed "
          "quantization') ==")
    for step in range(120):
        tokens = jnp.asarray(next(stream))
        params, opt_state, loss = train_step(params, opt_state, tokens)
        if step % 20 == 0:
            print(f"  step {step:4d}  loss {float(loss):.4f}")

    print("== quantize to PSI serving format (INT5, packed bit-planes) ==")
    qparams = model.quantize(params, bits=5, pack=True)
    serve_cfg = dataclasses.replace(cfg, quant_mode="psi5")
    serve_model = build_model(serve_cfg)

    prompt = jnp.asarray(next(stream))[:2, :16]
    logits, cache = serve_model.prefill(qparams, {"tokens": prompt},
                                        cache_len=48)
    tok = jnp.argmax(logits, -1)[:, None]
    out = [tok]
    for i in range(12):
        lg, cache = serve_model.decode_step(
            qparams, {"token": tok,
                      "pos": jnp.full((2, 1), 16 + i, jnp.int32)}, cache)
        tok = jnp.argmax(lg, -1)[:, None]
        out.append(tok)
    gen = jnp.concatenate(out, axis=1)
    print(f"  generated (psi5 weights, 0.625 B/weight): {gen[0].tolist()}")
    print("done.")


if __name__ == "__main__":
    main()
