"""Continuous-batching serving with PSI-compressed weights: the paper's
inference regime (weight traffic is the bottleneck) mapped to LM decode.

Runs the slot-based Server engine over an arrival trace for each weight
format and reports the serving-weight footprint — the quantity the
psi_matmul kernel translates into HBM-bandwidth savings on TPU.

  PYTHONPATH=src python examples/serve_quantized.py
"""
import dataclasses

import jax

from repro.configs import get_config, reduced_config
from repro.core.quantizer import quantized_bytes
from repro.launch.scheduler import poisson_trace
from repro.launch.serve import Server
from repro.models import build_model


def main():
    cfg = reduced_config(get_config("chatglm3-6b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    base_bytes = quantized_bytes(params)
    # uniform widths from the PsiFormat registry, plus a mixed-precision
    # policy (embeddings keep 8 bits, the bulk rides the sub-5-bit frontier)
    formats = (("none", dict()),
               ("psi8", dict(bits=8)),
               ("psi5", dict(bits=5, pack=True)),
               ("psi4", dict(bits=4, pack=True)),
               ("mixed", dict(policy={"embed": 8, "default": 4}, pack=True)))
    for quant, spec in formats:
        p = params if not spec else model.quantize(params, **spec)
        scfg = cfg if not spec else dataclasses.replace(
            cfg, quant_mode=quant if quant.startswith("psi") else "none")
        reqs = poisson_trace(4, rate_rps=500.0, prompt_len=24, max_new=8,
                             vocab_size=cfg.vocab_size, seed=0)
        server = Server(scfg, p, max_batch=4, max_seq=48)
        done, stats = server.serve(reqs, continuous=True)
        nbytes = quantized_bytes(p)
        print(f"{quant:5s}: {stats['tok_per_s']:8.1f} tok/s (CPU), "
              f"weights {nbytes/1e6:7.2f} MB ({base_bytes/nbytes:.2f}x smaller), "
              f"sample: {done[0].out[:6].tolist()}")


if __name__ == "__main__":
    main()
