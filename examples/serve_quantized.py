"""Batched serving with PSI-compressed weights: the paper's inference regime
(weight traffic is the bottleneck) mapped to LM decode.

Runs the Server engine (prefill + decode loop) over a batch of requests for
each weight format and reports the serving-weight footprint — the quantity
the psi_matmul kernel translates into HBM-bandwidth savings on TPU.

  PYTHONPATH=src python examples/serve_quantized.py
"""
import dataclasses

import jax
import numpy as np

from repro.configs import get_config, reduced_config
from repro.core.quantizer import quantized_bytes
from repro.launch.serve import Request, Server
from repro.models import build_model


def main():
    cfg = reduced_config(get_config("chatglm3-6b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    base_bytes = quantized_bytes(params)
    for quant, bits, pack in (("none", None, False), ("psi8", 8, False),
                              ("psi5", 5, True)):
        p = params if bits is None else model.quantize(params, bits, pack=pack)
        scfg = cfg if bits is None else dataclasses.replace(
            cfg, quant_mode=quant)
        reqs = [Request(i, rng.integers(0, cfg.vocab_size, size=(24,))
                        .astype(np.int32), max_new=8) for i in range(4)]
        server = Server(scfg, p, max_seq=48)
        done, stats = server.run_batch(reqs)
        nbytes = quantized_bytes(p)
        print(f"{quant:5s}: {stats['tok_per_s']:8.1f} tok/s (CPU), "
              f"weights {nbytes/1e6:7.2f} MB ({base_bytes/nbytes:.2f}x smaller), "
              f"sample: {done[0].out[:6].tolist()}")


if __name__ == "__main__":
    main()
