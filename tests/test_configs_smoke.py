"""Per-architecture smoke tests: reduced config, one forward + one train
step on CPU, asserting output shapes and finiteness; decode-vs-forward
consistency; PSI serving path on every family."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import (ASSIGNED_ARCHS, SHAPES, get_config, list_configs,
                           reduced_config, shape_applicable)
from repro.data.pipeline import make_batch_for
from repro.models import build_model


@pytest.fixture(scope="module", params=ASSIGNED_ARCHS)
def arch_setup(request):
    cfg = reduced_config(get_config(request.param))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch_for(cfg, 2, 24, jax.random.PRNGKey(1))
    return cfg, model, params, batch


def test_full_configs_match_assignment():
    assert set(ASSIGNED_ARCHS) <= set(list_configs())
    spec = {
        "chatglm3-6b": (28, 4096, 32, 2, 13696, 65024),
        "qwen3-8b": (36, 4096, 32, 8, 12288, 151936),
        "granite-34b": (88, 6144, 48, 1, 24576, 49152),
        "phi3-medium-14b": (40, 5120, 40, 10, 17920, 100352),
        "whisper-base": (6, 512, 8, 8, 2048, 51865),
        "qwen3-moe-30b-a3b": (48, 2048, 32, 4, 768, 151936),
        "mixtral-8x22b": (56, 6144, 48, 8, 16384, 32768),
        "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
        "qwen2-vl-2b": (28, 1536, 12, 2, 8960, 151936),
        "falcon-mamba-7b": (64, 4096, 0, 0, 0, 65024),
    }
    for name, (L, d, h, kv, ff, V) in spec.items():
        c = get_config(name)
        assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads,
                c.d_ff, c.vocab_size) == (L, d, h, kv, ff, V), name


def test_forward_shapes_and_finiteness(arch_setup):
    cfg, model, params, batch = arch_setup
    logits, _, aux, _ = model.forward(params, batch)
    B, S = batch["tokens"].shape
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(aux))


def test_train_step_no_nans(arch_setup):
    cfg, model, params, batch = arch_setup
    loss, grads = jax.value_and_grad(
        lambda p: model.loss(p, batch)[0])(params)
    assert bool(jnp.isfinite(loss))
    assert 0 < float(loss) < 20
    for leaf in jax.tree_util.tree_leaves(grads):
        assert bool(jnp.isfinite(leaf).all())


def test_decode_matches_forward(arch_setup):
    """One decoded token's logits == full forward on the extended sequence."""
    cfg, model, params, batch = arch_setup
    B, S = batch["tokens"].shape
    lp, cache = model.prefill(params, batch, cache_len=S + 4)
    tok = jnp.argmax(lp, -1)[:, None]
    db = {"token": tok, "pos": jnp.full((B, 1), S, jnp.int32)}
    if cfg.family == "vlm":
        db["positions"] = jnp.full((B, 3, 1), S, jnp.int32)
    lg, _ = model.decode_step(params, db, cache)
    b2 = dict(batch)
    b2["tokens"] = jnp.concatenate([batch["tokens"], tok], 1)
    if cfg.family == "vlm":
        b2["positions"] = jnp.concatenate(
            [batch["positions"], db["positions"]], -1)
    fl, _, _, _ = model.forward(params, b2)
    np.testing.assert_allclose(np.asarray(fl[:, -1], np.float32),
                               np.asarray(lg, np.float32),
                               rtol=2e-4, atol=2e-4)


def test_multi_step_decode(arch_setup):
    """Eight decode steps stay finite and shape-stable."""
    cfg, model, params, batch = arch_setup
    B, S = batch["tokens"].shape
    lp, cache = model.prefill(params, batch, cache_len=S + 16)
    tok = jnp.argmax(lp, -1)[:, None]
    for i in range(8):
        db = {"token": tok, "pos": jnp.full((B, 1), S + i, jnp.int32)}
        if cfg.family == "vlm":
            db["positions"] = jnp.full((B, 3, 1), S + i, jnp.int32)
        lg, cache = model.decode_step(params, db, cache)
        assert bool(jnp.isfinite(lg).all())
        tok = jnp.argmax(lg, -1)[:, None]


@pytest.mark.parametrize("bits,pack", [(8, False), (5, True)])
def test_psi_serving_path(arch_setup, bits, pack):
    """PSI-quantized forward stays close to the float forward (the paper's
    technique on every architecture family)."""
    cfg, model, params, batch = arch_setup
    fl, _, _, _ = model.forward(params, batch)
    qp = model.quantize(params, bits, pack=pack)
    mq = build_model(dataclasses.replace(cfg, quant_mode=f"psi{bits}"))
    ql, _, _, _ = mq.forward(qp, batch)
    rel = float(jnp.linalg.norm(ql - fl) / jnp.linalg.norm(fl))
    assert rel < (0.12 if bits == 8 else 0.55), rel
    # compression ratio of the quantizable weights
    from repro.core.quantizer import quantized_bytes
    assert quantized_bytes(qp) < quantized_bytes(params)


def test_qat_step_decreases_loss(arch_setup):
    """A few QAT-INT8 SGD steps reduce the loss (STE gradients flow)."""
    cfg, model, params, batch = arch_setup
    mq = build_model(dataclasses.replace(cfg, quant_mode="qat8"))
    loss0 = float(mq.loss(params, batch)[0])
    p = params
    for _ in range(5):
        g = jax.grad(lambda pp: mq.loss(pp, batch)[0])(p)
        p = jax.tree_util.tree_map(lambda a, b: a - 0.3 * b, p, g)
    loss1 = float(mq.loss(p, batch)[0])
    assert loss1 < loss0


def test_shape_applicability_matrix():
    """40 cells; long_500k runs only for bounded-state archs (DESIGN.md §4)."""
    total = runnable = 0
    long_ok = set()
    for a in ASSIGNED_ARCHS:
        cfg = get_config(a)
        for s, sh in SHAPES.items():
            total += 1
            ok, why = shape_applicable(cfg, sh)
            runnable += ok
            if ok and s == "long_500k":
                long_ok.add(a)
    assert total == 40
    assert long_ok == {"mixtral-8x22b", "recurrentgemma-9b",
                       "falcon-mamba-7b"}
    assert runnable == 33


def test_param_counts_in_expected_range():
    """Analytic param counts are in the class the model names claim."""
    expect = {"qwen3-8b": (7e9, 10e9), "granite-34b": (30e9, 40e9),
              "phi3-medium-14b": (12e9, 16e9), "mixtral-8x22b": (130e9, 150e9),
              "qwen3-moe-30b-a3b": (26e9, 34e9), "falcon-mamba-7b": (6e9, 9e9),
              "recurrentgemma-9b": (8e9, 12e9), "qwen2-vl-2b": (1.2e9, 2.5e9),
              "chatglm3-6b": (5e9, 8e9), "whisper-base": (6e7, 1.3e8)}
    for name, (lo, hi) in expect.items():
        n = get_config(name).param_count()
        assert lo < n < hi, (name, n)
