"""First-class quantized-weight API: mixed-precision policies, the typed
QuantizedTensor serving path (embedding gather included), and checkpoint
round-trips.  (Format-registry property tests live in test_psi.py; kernel
dispatch tests in test_kernels.py.)"""
import dataclasses
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import psi, quantizer
from repro.quant import embed, linear, tied_logits


def _rand(shape, seed=0):
    return jnp.asarray(np.random.default_rng(seed).normal(size=shape)
                       .astype(np.float32))


class TestPolicy:
    def test_parse_policy_string(self):
        p = quantizer.parse_policy("embed=8, w_down=4, default=5")
        assert p == {"embed": 8, "w_down": 4, "default": 5}
        with pytest.raises(ValueError):
            quantizer.parse_policy("embed=9,default=5")   # unregistered width
        with pytest.raises(ValueError):
            quantizer.parse_policy("embed:8")
        with pytest.raises(ValueError):
            quantizer.parse_policy("w(=5")        # malformed regex name

    def test_policy_assigns_per_leaf_formats(self):
        params = {"embed": _rand((32, 16)),
                  "stack": {"wq": _rand((16, 16), 1),
                            "w_down": _rand((16, 16), 2),
                            "norm": jnp.ones((16,))}}
        qp = quantizer.quantize_param_tree(
            params, policy={"embed": 8, "w_down": 4, "default": 5},
            pack=True)
        assert qp["embed"].fmt.bits == 8 and not qp["embed"].packed
        assert qp["stack"]["wq"].fmt.bits == 5 and qp["stack"]["wq"].packed
        assert qp["stack"]["w_down"].fmt.bits == 4
        assert not isinstance(qp["stack"]["norm"], psi.QuantizedTensor)

    def test_policy_zero_bits_keeps_float(self):
        params = {"wq": _rand((16, 16)), "w_up": _rand((16, 16), 1)}
        qp = quantizer.quantize_param_tree(
            params, policy={"wq": 0, "default": 5})
        assert not isinstance(qp["wq"], psi.QuantizedTensor)
        assert qp["w_up"].fmt.bits == 5

    def test_policy_typo_warns(self):
        """A policy key matching no leaf at all must warn loudly — a typo'd
        layer name silently dropping to default precision is the failure
        mixed precision exists to avoid."""
        import warnings
        params = {"embed": _rand((16, 8))}
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            quantizer.quantize_param_tree(
                params, policy={"embd": 8, "default": 5})   # typo
        assert any("matched no parameter leaf" in str(x.message) for x in w)

    def test_policy_on_excluded_leaf_does_not_warn(self):
        """A deliberate entry for an excluded (non-quantizable) leaf like
        the MoE router is intent, not a typo — no warning."""
        import warnings
        params = {"router": _rand((16, 4)), "wq": _rand((16, 16), 1)}
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            qp = quantizer.quantize_param_tree(
                params, policy={"router": 0, "default": 5})
        assert not any("matched no parameter leaf" in str(x.message)
                       for x in w)
        assert not isinstance(qp["router"], psi.QuantizedTensor)

    def test_policy_nonzero_bits_on_excluded_leaf_warns(self):
        """router=8 contradicts the exclude list (the router never
        quantizes) — that silent no-op must warn."""
        import warnings
        params = {"router": _rand((16, 4)), "wq": _rand((16, 16), 1)}
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            quantizer.quantize_param_tree(
                params, policy={"router": 8, "default": 5})
        assert any("have no effect" in str(x.message) for x in w)

    def test_uniform_bits_still_works(self):
        qp = quantizer.quantize_param_tree({"wq": _rand((16, 8))}, 8)
        assert qp["wq"].fmt.bits == 8

    def test_no_bits_no_policy_raises(self):
        with pytest.raises(ValueError):
            quantizer.quantize_param_tree({"wq": _rand((16, 8))})


class TestServingPaths:
    def test_packed_embedding_lookup_regression(self):
        """A packed (bit-plane) embedding leaf must serve lookups — the old
        dict path read wleaf["codes"] unconditionally and raised KeyError."""
        table = _rand((64, 16))
        q = psi.quantize_weights(table, 5, axis=1)     # per-row scales
        qp = q.pack()
        ids = jnp.asarray([[0, 7, 63], [8, 9, 10]])
        got = embed(qp, ids, jnp.float32)
        want = embed(q, ids, jnp.float32)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-6, atol=1e-6)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(q.dequantize(jnp.float32)[ids]),
            rtol=1e-6, atol=1e-6)

    def test_mixed_precision_embed_matches_uniform_psi8(self):
        """Acceptance: policy {"embed": 8, "default": 5} is token-identical
        to uniform psi8 on the embedding path (same format -> same codes)."""
        params = {"embed": _rand((128, 32)), "wq": _rand((32, 32), 1)}
        mixed = quantizer.quantize_param_tree(
            params, policy={"embed": 8, "default": 5}, pack=True)
        uni8 = quantizer.quantize_param_tree(params, 8)
        ids = jnp.asarray(np.random.default_rng(3).integers(0, 128, (4, 9)))
        np.testing.assert_array_equal(
            np.asarray(embed(mixed["embed"], ids, jnp.float32)),
            np.asarray(embed(uni8["embed"], ids, jnp.float32)))
        # the tied-logits head reads the same table: identical logits too
        x = _rand((4, 32), 5)
        np.testing.assert_array_equal(
            np.asarray(tied_logits(mixed["embed"], x)),
            np.asarray(tied_logits(uni8["embed"], x)))
        # while the 5-bit leaf actually changed format
        assert mixed["wq"].fmt.bits == 5 and uni8["wq"].fmt.bits == 8

    def test_linear_matches_dequantized_einsum(self):
        w = _rand((64, 24), 2)
        x = _rand((3, 64), 4)
        for bits in (4, 5, 8):
            q = psi.quantize_weights(w, bits, axis=0)
            for leaf in (q,) + ((q.pack(),) if q.fmt.sub_byte else ()):
                got = linear(leaf, x)
                want = x @ quantizer.dequantize(leaf, jnp.float32)
                np.testing.assert_allclose(np.asarray(got, np.float32),
                                           np.asarray(want, np.float32),
                                           rtol=2e-2, atol=2e-2)

    def test_shared_dequantize_passthrough(self):
        w = _rand((8, 8))
        assert quantizer.dequantize(w) is w


class TestCheckpointRoundtrip:
    def test_quantized_tree_survives_save_load(self):
        from repro.checkpoint.manager import CheckpointManager
        params = {"embed": _rand((32, 16)),
                  "stack": {"wq": _rand((16, 16), 1), "b": jnp.zeros((16,))}}
        qp = quantizer.quantize_param_tree(
            params, policy={"embed": 8, "default": 4}, pack=True)
        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(d)
            mgr.save(1, qp)
            tree, _ = mgr.restore(1)
        assert (jax.tree_util.tree_structure(tree)
                == jax.tree_util.tree_structure(qp))
        got = tree["stack"]["wq"]
        assert isinstance(got, psi.QuantizedTensor)
        assert got.fmt == qp["stack"]["wq"].fmt and got.packed
        for a, b in zip(jax.tree_util.tree_leaves(tree),
                        jax.tree_util.tree_leaves(qp)):
            assert np.array_equal(np.asarray(a), np.asarray(b))

    def test_custom_format_survives_save_load(self):
        """A non-default term budget (register_format(5, n_psi=3) is exact)
        must restore with ITS format, not the registry default's."""
        from repro.checkpoint.manager import CheckpointManager
        fmt3 = psi.make_format(5, n_psi=3)
        assert fmt3.exact                      # 3 terms cover all of INT5
        w = _rand((16, 8))
        scale = psi.compute_scale(w, fmt3, (0,))
        codes = jnp.clip(jnp.round(w / scale), fmt3.w_min,
                         fmt3.w_max).astype(jnp.int8)
        qt = psi.QuantizedTensor(codes, scale.astype(jnp.float32), fmt3)
        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(d)
            mgr.save(1, {"wq": qt})
            tree, _ = mgr.restore(1)
        got = tree["wq"].fmt
        assert got == fmt3 and got.n_psi == 3 and got.exact


class TestUnpackRowsGuard:
    def test_stacked_packed_table_rejected(self):
        """unpack_rows on a stacked (L, bits, K//8, N) table must raise, not
        silently gather garbage with the plane index applied to the L dim."""
        codes = jnp.asarray(np.random.default_rng(0).integers(
            -16, 16, size=(2, 16, 8)).astype(np.int8))
        packed = psi.pack_codes(codes, 5)        # (2, 5, 2, 8)
        with pytest.raises(ValueError):
            psi.unpack_rows(packed, jnp.asarray([0, 1]), 5)


class TestSubByteServing:
    def test_psi4_serves_token_stably(self):
        """Acceptance: an INT4 policy serves end-to-end through the slot
        engine on the reduced qwen3-8b config, token-identical between
        static and continuous scheduling."""
        from types import SimpleNamespace
        from repro.launch.serve import build_server, trace_from_args
        args = SimpleNamespace(
            arch="qwen3-8b", reduced=True, quant="psi4", quant_policy=None,
            requests=4, max_batch=2, arrival_rate=1000.0, max_new=6,
            min_new=2, prompt_len=12, prompt_jitter=0, eos_id=-1, seed=0,
            mesh=None)
        server, cfg = build_server(args)
        done_s, _ = server.serve(trace_from_args(args, cfg), continuous=False)
        done_c, stats = server.serve(trace_from_args(args, cfg),
                                     continuous=True, warmup=False)
        for rs, rc in zip(sorted(done_s, key=lambda r: r.rid),
                          sorted(done_c, key=lambda r: r.rid)):
            assert rs.tokens == rc.tokens
        assert stats["tokens"] > 0

    def test_quant_policy_cli_flag_builds(self):
        """--quant-policy threads from the CLI into per-leaf formats."""
        from types import SimpleNamespace
        from repro.launch.serve import build_server
        args = SimpleNamespace(
            arch="qwen3-8b", reduced=True, quant="none",
            quant_policy="embed=8,default=5", requests=1, max_batch=2,
            arrival_rate=1000.0, max_new=4, min_new=1, prompt_len=12,
            prompt_jitter=0, eos_id=-1, seed=0, mesh=None)
        server, cfg = build_server(args)
        p = server.executor.params
        assert p["embed"].fmt.bits == 8
        stack_wq = p["stack"]["groups"]["b0_attn"]["attn"]["wq"]
        assert stack_wq.fmt.bits == 5 and stack_wq.packed
        assert cfg.quant_mode == "psi5"
