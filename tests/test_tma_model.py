"""TMA accelerator cycle/energy/SRAM model vs the paper's published numbers
(Tables II-III, Figs. 8-9)."""
import math

import pytest

from repro.core import baselines as bl, tma_model as tm


class TestTable2:
    def test_macs_parallel(self):
        assert tm.MACS_PARALLEL == 2304      # 4x4x16 NEs x 9 SAMs

    def test_peak_throughput(self):
        assert tm.peak_throughput_gmacs(5, 250e6) == pytest.approx(576)
        assert tm.peak_throughput_gmacs(8, 250e6) == pytest.approx(288)

    def test_alexnet_frame_rate_order(self):
        """Paper: 62 fps @200 MHz.  The cycle model (no DRAM/control
        overheads) lands within ~30 %."""
        fr8 = tm.frame_rate(tm.alexnet_layers(), 8)
        assert 55 < fr8 < 95
        fr5 = tm.frame_rate(tm.alexnet_layers(), 5)
        assert fr5 > fr8          # INT5 strictly faster

    def test_fifo_capacity_rationale(self):
        assert tm.check_fifo_capacity(tm.alexnet_layers())

    def test_psum_sram_fits_4mb(self):
        need = tm.psum_sram_requirement_bytes(tm.alexnet_layers())
        assert need <= tm.SRAM_BYTES


class TestTable3:
    def test_tmacs_per_watt(self):
        assert tm.macs_per_watt(5) / 1e12 == pytest.approx(2.43, rel=0.01)
        assert tm.macs_per_watt(8) / 1e12 == pytest.approx(1.215, rel=0.01)

    def test_vs_convnet_ratio(self):
        """Paper: ~12.7x (INT5) and ~6.4x (INT8) over ConvNet GMACs/W."""
        conv = bl.CONVNET.gmacs_per_watt()
        r5 = tm.macs_per_watt(5) / 1e9 / conv
        r8 = tm.macs_per_watt(8) / 1e9 / conv
        assert r5 == pytest.approx(12.7, rel=0.05)
        assert r8 == pytest.approx(6.4, rel=0.05)

    def test_table3_rows_complete(self):
        rows = bl.table3_rows()
        names = [r["name"] for r in rows]
        assert names == ["Eyeriss", "ConvNet", "DSIP",
                         "TMA (INT5)", "TMA (INT8)"]


class TestFig8:
    """Per-layer AlexNet processing-time ratios (batch 4)."""

    @pytest.fixture
    def layers(self):
        return tm.alexnet_layers()

    def _t(self, layers, name, bits):
        rep = {r.name: r for r in tm.analyze_network(layers, bits, batch=4)}
        return rep[name].time_s

    def test_conv3_vs_eyeriss(self, layers):
        r = (bl.EYERISS.layer_time_s(layers[2], 4)
             / self._t(layers, "conv3", 5))
        assert r == pytest.approx(24.6, rel=0.05)

    def test_conv3_vs_dsip(self, layers):
        r = bl.DSIP.layer_time_s(layers[2], 4) / self._t(layers, "conv3", 5)
        assert r == pytest.approx(41.7, rel=0.05)

    def test_fc1_vs_eyeriss(self, layers):
        r5 = (bl.EYERISS.layer_time_s(layers[5], 4)
              / self._t(layers, "fc6", 5))
        r8 = (bl.EYERISS.layer_time_s(layers[5], 4)
              / self._t(layers, "fc6", 8))
        assert r5 == pytest.approx(14.9, rel=0.05)
        assert r8 == pytest.approx(13.9, rel=0.05)

    def test_conv1_int8_slower_than_eyeriss(self, layers):
        """Paper §IV-A: TMA INT8 Conv1 is SLOWER than Eyeriss (only
        11x11x3 of the 12x12x16 SAMs are used)."""
        assert self._t(layers, "conv1", 8) > bl.EYERISS.layer_time_s(layers[0], 4)

    def test_int8_cycle_ratios(self, layers):
        """INT8/INT5 = ~2x for stride-1 convs, ~1.25x for Conv1 (stride 4),
        <10% overhead for FC (paper §IV-A)."""
        c3 = self._t(layers, "conv3", 8) / self._t(layers, "conv3", 5)
        c1 = self._t(layers, "conv1", 8) / self._t(layers, "conv1", 5)
        f6 = self._t(layers, "fc6", 8) / self._t(layers, "fc6", 5)
        # "approximately twice": exact limit is (W_in+W_out)/W_in -> 2
        assert c3 == pytest.approx(2.0, rel=0.08)
        assert c1 == pytest.approx(1.25, rel=0.03)
        assert f6 < 1.10


class TestFig9:
    def test_psum_access_reduction_conv(self):
        """Paper: up to ~74x fewer Psum SRAM accesses in conv layers."""
        layers = tm.alexnet_layers()[:5]
        best = max(bl.EYERISS.psum_sram_accesses(l)
                   / tm.psum_sram_accesses_tma(l) for l in layers)
        assert 60 < best < 90

    def test_psum_access_reduction_fc(self):
        """Paper: up to ~240x in FC layers."""
        layers = tm.alexnet_layers()[5:]
        best = max(bl.EYERISS.psum_sram_accesses(l)
                   / tm.psum_sram_accesses_tma(l) for l in layers)
        assert 150 < best < 400


class TestGateModel:
    def test_total_calibrated(self):
        g = tm.gate_count_model()
        assert g["total"] == 294_000
        assert g["other"] > 0                 # array fits inside the budget
        assert g["moa18_vs_18cla_saving"] == pytest.approx(0.36)

    def test_power_scaling(self):
        assert tm.power_w(250e6) == pytest.approx(0.237)
        assert tm.power_w(125e6) == pytest.approx(0.237 / 2)
        assert tm.power_w(250e6, voltage=0.9) == pytest.approx(0.237 * 0.81)
