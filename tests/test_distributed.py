"""Distribution-layer tests that need multiple devices: run in a SUBPROCESS
with a forced CPU device count so the main test session keeps 1 device
(the dry-run flag must never leak into other tests)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(snippet: str, devices: int = 8, timeout: int = 480) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(snippet)],
                         capture_output=True, text=True, env=env,
                         timeout=timeout, cwd=REPO)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_sharded_train_step_matches_single_device():
    """Loss of a jit train step on a (2, 4) data x model mesh == 1-device."""
    out = _run("""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_config, reduced_config
        from repro.models import build_model
        from repro.runtime import sharding as shr
        from repro.launch.mesh import make_mesh

        cfg = reduced_config(get_config("qwen3-8b"))
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        batch = {"tokens": jax.random.randint(
            jax.random.PRNGKey(1), (8, 32), 0, cfg.vocab_size)}
        l1 = float(model.loss(params, batch)[0])

        mesh = make_mesh((2, 4), ("data", "model"))
        pspecs = shr.param_specs(params, cfg, mesh, mode="train")
        with mesh:
            psh = shr.to_shardings(pspecs, mesh)
            bsh = shr.to_shardings(shr.batch_specs(cfg, mesh, batch), mesh)
            pp = jax.device_put(params, psh)
            bb = jax.device_put(batch, bsh)
            l2 = float(jax.jit(lambda p, b: model.loss(p, b)[0],
                               in_shardings=(psh, bsh))(pp, bb))
        print("LOSSES", l1, l2)
        assert abs(l1 - l2) < 5e-3, (l1, l2)
    """)
    assert "LOSSES" in out


def test_psi_serving_sharded_matches_single_device():
    out = _run("""
        import dataclasses, jax, jax.numpy as jnp
        from repro.configs import get_config, reduced_config
        from repro.models import build_model
        from repro.runtime import sharding as shr
        from repro.launch.mesh import make_mesh

        cfg = reduced_config(get_config("chatglm3-6b"), quant_mode="psi8")
        model = build_model(cfg)
        p32 = build_model(dataclasses.replace(cfg, quant_mode="none")).init(
            jax.random.PRNGKey(0))
        qp = model.quantize(p32, 8)
        batch = {"tokens": jax.random.randint(
            jax.random.PRNGKey(1), (8, 16), 0, cfg.vocab_size)}
        ref, _, _, _ = model.forward(qp, batch)

        mesh = make_mesh((2, 4), ("data", "model"))
        with mesh:
            psh = shr.to_shardings(
                shr.param_specs(qp, cfg, mesh, mode="serve"), mesh)
            pp = jax.device_put(qp, psh)
            got, _, _, _ = jax.jit(model.forward)(pp, batch)
        import numpy as np
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(ref, np.float32),
                                   rtol=2e-3, atol=2e-3)
        print("OK")
    """)
    assert "OK" in out


def test_gpipe_pipeline_matches_sequential():
    """GPipe microbatch rotation over a 4-stage mesh == sequential apply."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import make_mesh
        from repro.runtime.pipeline_par import (pipeline_apply,
                                                pipeline_bubble_fraction)

        L, M, mb, d = 8, 6, 4, 16
        key = jax.random.PRNGKey(0)
        ws = jax.random.normal(key, (L, d, d)) * 0.2
        xs = jax.random.normal(jax.random.fold_in(key, 1), (M, mb, d))

        def layer_fn(w, x):
            return jnp.tanh(x @ w)

        seq = xs
        for i in range(L):
            seq = jax.vmap(lambda x: layer_fn(ws[i], x))(seq)

        mesh = make_mesh((4,), ("stage",))
        got = pipeline_apply(layer_fn, ws, xs, mesh, stage_axis="stage")
        np.testing.assert_allclose(np.asarray(got), np.asarray(seq),
                                   rtol=1e-5, atol=1e-5)
        assert abs(pipeline_bubble_fraction(6, 4) - 3/9) < 1e-9
        print("OK")
    """)
    assert "OK" in out


def test_elastic_restart_resharded():
    """Checkpoint on an 8-device mesh, restore onto a 4-device mesh."""
    out = _run("""
        import tempfile, jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint import CheckpointManager
        from repro.launch.mesh import make_mesh
        from repro.runtime.elastic import plan_remesh, make_mesh_from_plan

        d = tempfile.mkdtemp()
        mesh8 = make_mesh((2, 4), ("data", "model"))
        w = jax.device_put(jnp.arange(64.0).reshape(8, 8),
                           NamedSharding(mesh8, P("data", "model")))
        mgr = CheckpointManager(d)
        mgr.save(1, {"w": w}, extra={"step": 1})

        plan = plan_remesh(4, model_parallel=2)
        mesh4 = make_mesh_from_plan(plan)
        sh = NamedSharding(mesh4, P("data", "model"))
        got, extra = mgr.restore(shardings={"w": sh})
        assert got["w"].sharding == sh
        np.testing.assert_array_equal(np.asarray(got["w"]),
                                      np.arange(64.0).reshape(8, 8))
        print("OK", extra["step"])
    """)
    assert "OK 1" in out


def test_executor_prefill_decode_matches_single_device():
    """Executor on a (4, 2) data x model mesh: prefill logits and a decode
    step must match the 1-device Executor (allclose at the serving dtype)
    for BOTH qat-float and PSI-packed (bit-plane) params."""
    out = _run("""
        import dataclasses, jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config, reduced_config
        from repro.launch.mesh import make_mesh
        from repro.models import build_model
        from repro.runtime.executor import Executor

        # dense layout pinned: this test exercises the slot-slab machinery
        # (insert_burst row writes); the paged twin lives in
        # test_paged_serving_sharded_matches_dense_single_device
        base = reduced_config(get_config("qwen3-8b"), cache_layout="dense")
        model = build_model(base)
        p32 = model.init(jax.random.PRNGKey(0))
        flavors = {
            "qat-float": (dataclasses.replace(base, quant_mode="qat8"), p32),
            "psi-packed": (dataclasses.replace(base, quant_mode="psi5"),
                           model.quantize(p32, 5, pack=True)),
        }
        mesh8 = make_mesh((4, 2), ("data", "model"))
        toks = np.asarray(
            jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0,
                               base.vocab_size), np.int32)
        tl = np.full((4,), 16, np.int32)
        for name, (cfg, params) in flavors.items():
            mdl = build_model(cfg)
            ex1 = Executor(cfg, params, max_batch=4, max_seq=32)
            ex8 = Executor(cfg, params, max_batch=4, max_seq=32, mesh=mesh8)
            assert ex8.n_slot_shards == 4, ex8.n_slot_shards
            # raw prefill logits: sharded == single-device (f32 on CPU)
            lg1, _ = jax.jit(mdl.prefill)(ex1.params,
                                          {"tokens": jnp.asarray(toks)})
            lg8, _ = jax.jit(mdl.prefill)(ex8.params,
                                          {"tokens": jnp.asarray(toks)})
            np.testing.assert_allclose(np.asarray(lg1, np.float32),
                                       np.asarray(lg8, np.float32),
                                       rtol=2e-3, atol=2e-3)
            f1, c1 = ex1.prefill(toks, tl)
            f8, c8 = ex8.prefill(toks, tl)
            np.testing.assert_array_equal(np.asarray(f1), np.asarray(f8))
            # one decode step from the prefilled state, all slots active
            cache1, cache8 = ex1.init_cache(), ex8.init_cache()
            slots = np.arange(4, dtype=np.int32)
            cache1 = ex1.insert_burst(cache1, c1, slots, np.ones(4, bool))
            cache8 = ex8.insert_burst(cache8, c8, slots, np.ones(4, bool))
            tok = np.asarray(f1).reshape(4, 1)
            pos = np.full((4, 1), 16, np.int32)
            act = np.ones((4,), bool)
            t1, _ = ex1.decode(tok, pos, act, cache1)
            t8, _ = ex8.decode(tok, pos, act, cache8)
            np.testing.assert_array_equal(np.asarray(t1), np.asarray(t8))
            print("OK", name)
    """)
    assert "OK qat-float" in out and "OK psi-packed" in out


def test_sharded_serving_tokens_identical():
    """Full serve loop on a forced 8-device (4, 2) mesh: slots partition
    over the data axis and every request's token stream is identical to the
    single-device engine (greedy decode; scheduling/sharding may change
    *where* work runs, never the tokens)."""
    out = _run("""
        import dataclasses, jax, numpy as np
        from repro.configs import get_config, reduced_config
        from repro.launch.mesh import make_mesh
        from repro.launch.scheduler import Request
        from repro.launch.serve import Server
        from repro.models import build_model

        cfg = reduced_config(get_config("qwen3-8b"))
        model = build_model(cfg)
        params = model.quantize(model.init(jax.random.PRNGKey(0)), 8)
        cfg = dataclasses.replace(cfg, quant_mode="psi8")
        rng = np.random.default_rng(0)
        prompts = [rng.integers(0, cfg.vocab_size, size=(8,))
                   .astype(np.int32) for _ in range(6)]
        def mk():
            return [Request(rid=i, prompt=prompts[i], max_new=mn,
                            arrival_s=0.0)
                    for i, mn in enumerate([3, 7, 2, 5, 4, 6])]

        s1 = Server(cfg, params, max_batch=4, max_seq=64)
        d1, st1 = s1.serve(mk(), continuous=True)
        s8 = Server(cfg, params, max_batch=4, max_seq=64,
                    mesh=make_mesh((4, 2), ("data", "model")))
        d8, st8 = s8.serve(mk(), continuous=True)
        assert st1["slot_shards"] == 1 and st8["slot_shards"] == 4
        assert st8["decode_compiles"] == 1, st8["decode_compiles"]
        t1 = {r.rid: r.tokens for r in d1}
        t8 = {r.rid: r.tokens for r in d8}
        assert t1 == t8, (t1, t8)
        # slots really spread over the data axis: the first max_batch
        # admissions land one per shard
        shards = {s8.executor.slot_shards[r.slot]
                  for r in d8 if r.rid < 4}
        assert shards == {0, 1, 2, 3}, shards
        print("OK", st8["slot_shards"])
    """)
    assert "OK 4" in out


def test_paged_serving_sharded_matches_dense_single_device():
    """Acceptance: the paged layout on a forced 8-device (4, 2) mesh —
    block pools sharded block-over-data, block tables as decode-step inputs
    — produces token streams identical to BOTH the single-device dense
    engine and the sharded dense engine, in continuous and static modes,
    with the decode step compiling exactly once per server."""
    out = _run("""
        import dataclasses, jax, numpy as np
        from repro.configs import get_config, reduced_config
        from repro.launch.mesh import make_mesh
        from repro.launch.scheduler import Request
        from repro.launch.serve import Server
        from repro.models import build_model

        cfg = reduced_config(get_config("qwen3-8b"))
        model = build_model(cfg)
        params = model.quantize(model.init(jax.random.PRNGKey(0)), 8)
        cfg = dataclasses.replace(cfg, quant_mode="psi8")
        assert cfg.resolved_cache_layout == "paged"
        dense_cfg = dataclasses.replace(cfg, cache_layout="dense")
        rng = np.random.default_rng(0)
        prompts = [rng.integers(0, cfg.vocab_size, size=(5 + 3 * i,))
                   .astype(np.int32) for i in range(6)]
        def mk():
            return [Request(rid=i, prompt=prompts[i], max_new=mn,
                            arrival_s=0.0)
                    for i, mn in enumerate([3, 7, 2, 5, 4, 6])]
        toks = lambda done: {r.rid: tuple(r.tokens) for r in done}

        ref = Server(dense_cfg, params, max_batch=4, max_seq=64)
        t_ref = toks(ref.serve(mk(), continuous=True)[0])

        mesh = make_mesh((4, 2), ("data", "model"))
        sd = Server(dense_cfg, params, max_batch=4, max_seq=64, mesh=mesh)
        d_d, st_d = sd.serve(mk(), continuous=True)
        sp = Server(cfg, params, max_batch=4, max_seq=64,
                    mesh=make_mesh((4, 2), ("data", "model")))
        ex = sp.executor
        assert ex.paged and ex.n_slot_shards == 4
        assert ex.n_block_shards == 4, ex.n_block_shards
        # block->shard map follows GSPMD chunking of the full pool dim
        assert len(ex.block_shards) == ex.n_blocks
        d_pc, st_pc = sp.serve(mk(), continuous=True)
        d_ps, st_ps = sp.serve(mk(), continuous=False)
        assert toks(d_d) == t_ref
        assert toks(d_pc) == t_ref and toks(d_ps) == t_ref
        assert st_pc["decode_compiles"] == 1, st_pc["decode_compiles"]
        assert st_d["decode_compiles"] == 1
        assert st_pc["cache_layout"] == "paged"
        assert st_pc["blocks_free_end"] == st_pc["n_blocks"]
        print("OK", st_pc["slot_shards"], st_pc["n_blocks"])
    """)
    assert "OK 4" in out


def test_paged_kernel_no_recompile_on_mesh():
    """(4,2)-mesh twin of test_serving's table-content stability test: the
    routed paged-decode kernel path with the block pool sharded
    block-over-data still compiles the decode step exactly once across
    steps whose block tables differ only in content (fresh / permuted /
    freed / reused-with-holes)."""
    out = _run("""
        import dataclasses, jax, numpy as np
        from repro.configs import get_config, reduced_config
        from repro.launch.mesh import make_mesh
        from repro.models import build_model
        from repro.runtime.executor import Executor

        cfg = reduced_config(get_config("qwen3-8b"))
        model = build_model(cfg)
        params = model.quantize(model.init(jax.random.PRNGKey(0)), 8)
        cfg = dataclasses.replace(cfg, quant_mode="psi8")
        mesh = make_mesh((4, 2), ("data", "model"))
        ex = Executor(cfg, params, max_batch=4, max_seq=64, mesh=mesh)
        assert ex.paged and ex.paged_attn_route == "ref", ex.paged_attn_route
        B, n_bt = ex.max_batch, ex.n_bt
        cache = ex.init_cache()
        tok = np.zeros((B, 1), np.int32)
        pos = np.ones((B, 1), np.int32)
        act = np.ones((B,), bool)
        tables = [
            np.arange(B * n_bt, dtype=np.int32).reshape(B, n_bt),
            np.arange(B * n_bt, dtype=np.int32)[::-1].reshape(B, n_bt),
            np.full((B, n_bt), -1, np.int32),
            np.roll(np.arange(B * n_bt, dtype=np.int32), 5).reshape(B, n_bt),
        ]
        tables[3][:, -1] = -1
        for bt in tables:
            _, cache = ex.decode(tok, pos, act, cache, block_table=bt)
        assert ex.decode_cache_size() == 1, ex.decode_cache_size()
        print("OK", ex.decode_cache_size())
    """)
    assert "OK 1" in out


def test_executor_elastic_remesh_and_straggler_noop():
    """The executor's elastic hooks: from_devices sizes the mesh with
    plan_remesh, remesh() is a no-op when the plan matches, and the
    straggler monitor is None (no-op) on a single-process run."""
    out = _run("""
        import dataclasses, jax, numpy as np
        from repro.configs import get_config, reduced_config
        from repro.models import build_model
        from repro.runtime.executor import Executor

        cfg = reduced_config(get_config("qwen3-8b"))
        model = build_model(cfg)
        params = model.quantize(model.init(jax.random.PRNGKey(0)), 8)
        cfg = dataclasses.replace(cfg, quant_mode="psi8")

        ex = Executor.from_devices(cfg, params, max_batch=4, max_seq=32,
                                   model_parallel=2)
        assert dict(ex.mesh.shape) == {"data": 4, "model": 2}, ex.mesh.shape
        assert ex.remesh() is ex                      # plan matches: no-op
        ex4 = ex.remesh(devices=jax.devices()[:4])    # shrink data axis
        assert dict(ex4.mesh.shape) == {"data": 2, "model": 2}
        # same count but a swapped device (hot spare replacing a dead
        # chip): the plan shape matches yet remesh MUST rebuild
        ex_sw = ex4.remesh(devices=jax.devices()[4:8])
        assert ex_sw is not ex4
        assert dict(ex_sw.mesh.shape) == {"data": 2, "model": 2}
        assert ex.observe_step([1.0]) is None         # single-process no-op

        ex1 = Executor.from_devices(cfg, params, max_batch=4, max_seq=32,
                                    devices=jax.devices()[:1])
        assert dict(ex1.mesh.shape) == {"data": 1, "model": 1}
        assert ex1.n_slot_shards == 1 and ex1.monitor is None
        print("OK")
    """)
    assert "OK" in out


def test_dryrun_entry_on_tiny_mesh():
    """The dry-run machinery itself (build_step -> lower -> compile ->
    roofline report) on an 8-device mesh with a reduced arch."""
    out = _run("""
        import jax, numpy as np
        from repro.launch import dryrun as dr
        from repro.launch.mesh import make_mesh
        import repro.launch.dryrun  # noqa
        mesh = make_mesh((2, 4), ("data", "model"))
        with mesh:
            fn, args, in_sh, out_sh = dr.build_step(
                "whisper-base", "train_4k", "psi8", mesh)
        # whisper is the only arch small enough to lower quickly at full
        # config on 8 CPU devices
        with mesh:
            lowered = jax.jit(fn, in_shardings=in_sh,
                              out_shardings=out_sh).lower(*args)
            compiled = lowered.compile()
        mem = compiled.memory_analysis()
        assert mem.temp_size_in_bytes > 0
        coll, ops = dr.collective_bytes_per_device(compiled.as_text())
        print("OK", coll >= 0, sorted(ops))
    """, devices=8, timeout=560)
    assert "OK True" in out


def test_multistep_decode_sharded_token_identical():
    """Horizon-8 multi-step decode on a forced 8-device (4, 2) mesh (slots
    and paged blocks partitioned over the data axis, the round carry pinned
    to the same slot-over-data shardings) emits exactly the single-device
    horizon-1 streams, the scan compiling once; remesh preserves the
    horizon so an elastic restart keeps the multi-step entry point."""
    out = _run("""
        import dataclasses, jax, numpy as np
        from repro.configs import get_config, reduced_config
        from repro.launch.mesh import make_mesh
        from repro.launch.scheduler import Request
        from repro.launch.serve import Server
        from repro.models import build_model

        cfg = reduced_config(get_config("qwen3-8b"))
        model = build_model(cfg)
        params = model.quantize(model.init(jax.random.PRNGKey(0)), 8)
        cfg = dataclasses.replace(cfg, quant_mode="psi8")
        assert cfg.resolved_cache_layout == "paged"
        rng = np.random.default_rng(0)
        prompts = [rng.integers(0, cfg.vocab_size, size=(8,))
                   .astype(np.int32) for _ in range(6)]
        def mk():
            return [Request(rid=i, prompt=prompts[i], max_new=mn,
                            arrival_s=0.0)
                    for i, mn in enumerate([3, 7, 2, 13, 4, 9])]
        toks = lambda done: {r.rid: tuple(r.tokens) for r in done}

        ref = Server(cfg, params, max_batch=4, max_seq=64)
        t_ref = toks(ref.serve(mk(), continuous=True)[0])

        s8 = Server(cfg, params, max_batch=4, max_seq=64,
                    mesh=make_mesh((4, 2), ("data", "model")),
                    decode_horizon=8)
        d8, st8 = s8.serve(mk(), continuous=True)
        assert toks(d8) == t_ref, (toks(d8), t_ref)
        assert st8["slot_shards"] == 4
        assert st8["decode_horizon"] == 8
        assert st8["decode_compiles"] == 1, st8["decode_compiles"]
        assert s8.executor.multi_cache_sizes() == \\
            {"decode_multi": 1, "decode": 0}
        assert st8["host_syncs_per_token"] < 0.5
        assert st8["blocks_free_end"] == st8["n_blocks"]

        # elastic restart keeps the horizon (and its compiled entry)
        ex4 = s8.executor.remesh(devices=jax.devices()[:4])
        assert ex4.decode_horizon == 8
        assert ex4.decode_multi_cache_size() == 0   # fresh cache, not lost
        assert ex4._decode_multi is not None
        print("OK", st8["slot_shards"])
    """)
    assert "OK 4" in out
