"""Distribution-layer tests that need multiple devices: run in a SUBPROCESS
with a forced CPU device count so the main test session keeps 1 device
(the dry-run flag must never leak into other tests)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(snippet: str, devices: int = 8, timeout: int = 480) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(snippet)],
                         capture_output=True, text=True, env=env,
                         timeout=timeout, cwd=REPO)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_sharded_train_step_matches_single_device():
    """Loss of a jit train step on a (2, 4) data x model mesh == 1-device."""
    out = _run("""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_config, reduced_config
        from repro.models import build_model
        from repro.runtime import sharding as shr
        from repro.launch.mesh import make_mesh

        cfg = reduced_config(get_config("qwen3-8b"))
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        batch = {"tokens": jax.random.randint(
            jax.random.PRNGKey(1), (8, 32), 0, cfg.vocab_size)}
        l1 = float(model.loss(params, batch)[0])

        mesh = make_mesh((2, 4), ("data", "model"))
        pspecs = shr.param_specs(params, cfg, mesh, mode="train")
        with mesh:
            psh = shr.to_shardings(pspecs, mesh)
            bsh = shr.to_shardings(shr.batch_specs(cfg, mesh, batch), mesh)
            pp = jax.device_put(params, psh)
            bb = jax.device_put(batch, bsh)
            l2 = float(jax.jit(lambda p, b: model.loss(p, b)[0],
                               in_shardings=(psh, bsh))(pp, bb))
        print("LOSSES", l1, l2)
        assert abs(l1 - l2) < 5e-3, (l1, l2)
    """)
    assert "LOSSES" in out


def test_psi_serving_sharded_matches_single_device():
    out = _run("""
        import dataclasses, jax, jax.numpy as jnp
        from repro.configs import get_config, reduced_config
        from repro.models import build_model
        from repro.runtime import sharding as shr
        from repro.launch.mesh import make_mesh

        cfg = reduced_config(get_config("chatglm3-6b"), quant_mode="psi8")
        model = build_model(cfg)
        p32 = build_model(dataclasses.replace(cfg, quant_mode="none")).init(
            jax.random.PRNGKey(0))
        qp = model.quantize(p32, 8)
        batch = {"tokens": jax.random.randint(
            jax.random.PRNGKey(1), (8, 16), 0, cfg.vocab_size)}
        ref, _, _, _ = model.forward(qp, batch)

        mesh = make_mesh((2, 4), ("data", "model"))
        with mesh:
            psh = shr.to_shardings(
                shr.param_specs(qp, cfg, mesh, mode="serve"), mesh)
            pp = jax.device_put(qp, psh)
            got, _, _, _ = jax.jit(model.forward)(pp, batch)
        import numpy as np
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(ref, np.float32),
                                   rtol=2e-3, atol=2e-3)
        print("OK")
    """)
    assert "OK" in out


def test_gpipe_pipeline_matches_sequential():
    """GPipe microbatch rotation over a 4-stage mesh == sequential apply."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import make_mesh
        from repro.runtime.pipeline_par import (pipeline_apply,
                                                pipeline_bubble_fraction)

        L, M, mb, d = 8, 6, 4, 16
        key = jax.random.PRNGKey(0)
        ws = jax.random.normal(key, (L, d, d)) * 0.2
        xs = jax.random.normal(jax.random.fold_in(key, 1), (M, mb, d))

        def layer_fn(w, x):
            return jnp.tanh(x @ w)

        seq = xs
        for i in range(L):
            seq = jax.vmap(lambda x: layer_fn(ws[i], x))(seq)

        mesh = make_mesh((4,), ("stage",))
        got = pipeline_apply(layer_fn, ws, xs, mesh, stage_axis="stage")
        np.testing.assert_allclose(np.asarray(got), np.asarray(seq),
                                   rtol=1e-5, atol=1e-5)
        assert abs(pipeline_bubble_fraction(6, 4) - 3/9) < 1e-9
        print("OK")
    """)
    assert "OK" in out


def test_elastic_restart_resharded():
    """Checkpoint on an 8-device mesh, restore onto a 4-device mesh."""
    out = _run("""
        import tempfile, jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint import CheckpointManager
        from repro.launch.mesh import make_mesh
        from repro.runtime.elastic import plan_remesh, make_mesh_from_plan

        d = tempfile.mkdtemp()
        mesh8 = make_mesh((2, 4), ("data", "model"))
        w = jax.device_put(jnp.arange(64.0).reshape(8, 8),
                           NamedSharding(mesh8, P("data", "model")))
        mgr = CheckpointManager(d)
        mgr.save(1, {"w": w}, extra={"step": 1})

        plan = plan_remesh(4, model_parallel=2)
        mesh4 = make_mesh_from_plan(plan)
        sh = NamedSharding(mesh4, P("data", "model"))
        got, extra = mgr.restore(shardings={"w": sh})
        assert got["w"].sharding == sh
        np.testing.assert_array_equal(np.asarray(got["w"]),
                                      np.arange(64.0).reshape(8, 8))
        print("OK", extra["step"])
    """)
    assert "OK 1" in out


def test_dryrun_entry_on_tiny_mesh():
    """The dry-run machinery itself (build_step -> lower -> compile ->
    roofline report) on an 8-device mesh with a reduced arch."""
    out = _run("""
        import jax, numpy as np
        from repro.launch import dryrun as dr
        from repro.launch.mesh import make_mesh
        import repro.launch.dryrun  # noqa
        mesh = make_mesh((2, 4), ("data", "model"))
        with mesh:
            fn, args, in_sh, out_sh = dr.build_step(
                "whisper-base", "train_4k", "psi8", mesh)
        # whisper is the only arch small enough to lower quickly at full
        # config on 8 CPU devices
        with mesh:
            lowered = jax.jit(fn, in_shardings=in_sh,
                              out_shardings=out_sh).lower(*args)
            compiled = lowered.compile()
        mem = compiled.memory_analysis()
        assert mem.temp_size_in_bytes > 0
        coll, ops = dr.collective_bytes_per_device(compiled.as_text())
        print("OK", coll >= 0, sorted(ops))
    """, devices=8, timeout=560)
    assert "OK True" in out
