"""Prefix-cache subsystem tests (DESIGN.md §3 "Prefix cache"): refcounted
BlockAllocator share/fork invariants, PrefixCache chain lookup / publish /
LRU eviction, the serving-metrics satellite regressions, and the
end-to-end shared-prefix acceptance (token-identical with the cache on vs
off, measured hit rate, fewer prefilled tokens) on reduced qwen3-8b."""
import dataclasses
import json
import random

import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config, reduced_config
from repro.launch.prefix_cache import PrefixCache
from repro.launch.scheduler import (BlockAllocator, Request, poisson_trace,
                                    summarize)
from repro.launch.serve import Server, parse_mesh_spec
from repro.models import build_model


# ---------------------------------------------------------------------------
# Refcounted BlockAllocator: share / fork invariants.
# ---------------------------------------------------------------------------
class TestRefcounts:
    @given(st.integers(6, 40), st.integers(1, 4), st.integers(0, 10_000))
    @settings(max_examples=40, deadline=None)
    def test_share_churn_invariants(self, n_blocks, n_shards, seed):
        """Random alloc/attach/pin/release interleavings: a block with
        references remaining is never freed, ``free + in_use == n_blocks``
        holds counting shared blocks ONCE, and releasing every request and
        pin restores the exact initial free set."""
        alloc = BlockAllocator(n_blocks, n_shards=n_shards)
        initial_free = sorted(b for pool in alloc._free for b in pool)
        rng = random.Random(seed)
        live = {}                                  # rid -> referenced blocks
        pinned = []                                # cache-style pins
        for rid in range(rng.randint(2, 25)):
            if live and rng.random() < 0.35:
                victim = rng.choice(list(live))
                survivors = [b for b in live.pop(victim)
                             if alloc.refcount[b] > 1]
                alloc.release(victim)
                for b in survivors:               # refs remaining -> alive
                    assert alloc.refcount[b] >= 1
                    assert b not in [x for p in alloc._free for x in p]
            need = rng.randint(1, max(1, n_blocks // 3))
            if not alloc.can_reserve(need):
                continue
            mine = []
            # attach a shared run first (logical order), maybe
            sharable = [b for bs_ in live.values() for b in bs_] + pinned
            if sharable and rng.random() < 0.5:
                share = rng.sample(sharable, rng.randint(1, len(sharable)))
                share = list(dict.fromkeys(share))
                alloc.attach(rid, share)
                mine += share
            alloc.reserve(rid, need)
            for _ in range(rng.randint(0, need)):
                blk = alloc.alloc(rid)
                assert alloc.refcount[blk] == 1    # exclusive at birth
                mine.append(blk)
                if rng.random() < 0.3:             # cache publishes it
                    alloc.ref_block(blk)
                    pinned.append(blk)
            live[rid] = mine
            assert alloc.free_count + alloc.in_use == n_blocks
            # shared blocks count once: in_use == distinct referenced ids
            referenced = {b for bs_ in live.values() for b in bs_} | set(pinned)
            assert alloc.in_use == len(referenced)
        for rid in list(live):
            alloc.release(rid)
        for b in pinned:
            alloc.unref_block(b)
        assert alloc.free_count == n_blocks
        assert all(r == 0 for r in alloc.refcount)
        assert sorted(b for pool in alloc._free for b in pool) == initial_free
        assert all(o is None for o in alloc.owner)

    def test_release_never_frees_shared_block(self):
        alloc = BlockAllocator(4)
        alloc.reserve(1, 2)
        b0, b1 = alloc.alloc(1), alloc.alloc(1)
        alloc.ref_block(b0)                        # cache pin
        alloc.release(1)
        assert alloc.refcount[b0] == 1             # pinned -> alive
        assert alloc.refcount[b1] == 0             # exclusive -> freed
        assert alloc.free_count == 3
        assert alloc.unref_block(b0)               # last ref frees
        assert alloc.free_count == 4

    def test_attach_requires_populated_block(self):
        alloc = BlockAllocator(4)
        with pytest.raises(ValueError, match="free block"):
            alloc.attach(1, [0])
        with pytest.raises(ValueError, match="free block"):
            alloc.ref_block(0)

    def test_fork_cow_semantics(self):
        """COW fork: an exclusive block forks to itself; a shared block is
        swapped for a fresh exclusive one (old refs intact, reservation
        drawn down, logical position preserved)."""
        alloc = BlockAllocator(6)
        alloc.reserve(1, 2)
        b0, b1 = alloc.alloc(1), alloc.alloc(1)
        alloc.reserve(2, 1)
        alloc.attach(2, [b0, b1])
        assert alloc.is_shared(b0) and alloc.is_shared(b1)
        new = alloc.fork(2, b1)                    # shared -> copy
        assert new not in (b0, b1)
        assert alloc.refcount[b1] == 1 and alloc.refcount[new] == 1
        assert alloc.owned_by(2) == [b0, new]      # order preserved
        with pytest.raises(ValueError, match="beyond its reservation"):
            alloc.fork(2, b0)                      # shared, budget spent
        alloc.release(1)
        alloc.release(2)
        assert alloc.free_count == 6

    def test_fork_exclusive_is_identity(self):
        alloc = BlockAllocator(4)
        alloc.reserve(1, 2)
        b0 = alloc.alloc(1)
        assert alloc.fork(1, b0) == b0
        assert alloc._reserved[1] == 1             # no budget consumed


# ---------------------------------------------------------------------------
# PrefixCache: hash chains, publish, LRU eviction.
# ---------------------------------------------------------------------------
def _tok(*vals):
    return np.asarray(vals, np.int32)


class TestPrefixCache:
    def _published(self, alloc, pc, prompt, rid, tail=1):
        """Simulate a retiring request: ``nfull`` publishable prompt
        blocks plus ``tail`` decode/partial blocks that free at release."""
        nfull = len(prompt) // pc.block_size
        alloc.reserve(rid, nfull + tail)
        held = [alloc.alloc(rid) for _ in range(nfull + tail)]
        pc.publish(prompt, held, alloc)
        alloc.release(rid)
        return held

    def test_block_aligned_chain_lookup(self):
        alloc = BlockAllocator(16)
        pc = PrefixCache(4)
        prompt = np.arange(10, dtype=np.int32)      # 2 full blocks + tail
        held = self._published(alloc, pc, prompt, rid=1)
        assert len(pc) == 2                         # only full blocks enter
        # identical prompt: both full blocks hit (suffix 10-8=2 remains)
        assert pc.lookup(prompt) == held[:2]
        # diverging second block: only the first chains
        other = prompt.copy()
        other[5] = 99
        assert pc.lookup(other) == held[:1]
        # block-aligned prompt: hit capped to leave >=1 suffix token
        assert pc.lookup(prompt[:8]) == held[:1]
        # too short to cover any full block + 1
        assert pc.lookup(prompt[:4]) == []

    def test_publish_dedups_first_wins(self):
        alloc = BlockAllocator(16)
        pc = PrefixCache(4)
        prompt = np.arange(8, dtype=np.int32)
        held_a = self._published(alloc, pc, prompt, rid=1)
        held_b = self._published(alloc, pc, prompt, rid=2)
        assert pc.lookup(np.arange(9, dtype=np.int32)) == held_a[:2]
        assert held_b[0] != held_a[0] or alloc.refcount[held_b[0]] == 0

    def test_lru_eviction_restores_initial_free_set(self):
        """Publish until the pool is full of cached blocks, evict under
        pressure (LRU order, unreferenced entries only), then drain: the
        allocator must return to its EXACT initial free set."""
        alloc = BlockAllocator(8)
        initial_free = sorted(b for pool in alloc._free for b in pool)
        pc = PrefixCache(2)
        for rid in range(4):                        # 4 prompts x 2 blocks
            prompt = _tok(rid * 10, rid * 10 + 1, rid * 10 + 2,
                          rid * 10 + 3)
            self._published(alloc, pc, prompt, rid, tail=0)
        # publishes pinned blocks; nothing free beyond the +1 tails
        assert alloc.in_use == 8
        assert len(pc) == 8
        # touch rid 0's entries so rid 1's become LRU victims
        pc.lookup(_tok(0, 1, 2, 3, 4))
        evicted = pc.evict_until(alloc, need=2)
        assert evicted == 2
        assert alloc.can_reserve(2)
        # rid 1's chain is gone, rid 0's survives
        assert pc.lookup(_tok(10, 11, 12, 13, 14)) == []
        assert len(pc.lookup(_tok(0, 1, 2, 3, 4))) == 2
        pc.drain(alloc)
        assert len(pc) == 0
        assert sorted(b for pool in alloc._free for b in pool) == initial_free
        assert all(r == 0 for r in alloc.refcount)

    def test_eviction_takes_leaves_before_roots(self):
        """Regression: LRU order within a chain must be deepest-first —
        evicting a chain ROOT would orphan its still-pinned descendants
        (unreachable entries holding pool blocks).  One eviction from a
        4-block chain must remove the deepest entry, leaving a working
        3-block hit."""
        alloc = BlockAllocator(8)
        pc = PrefixCache(2)
        prompt = np.arange(8, dtype=np.int32)       # 4 full blocks
        held = self._published(alloc, pc, prompt, rid=1, tail=0)
        assert pc.evict_until(alloc, need=5) == 1
        assert pc.lookup(np.arange(9, dtype=np.int32)) == held[:3]
        # same after a lookup re-touches the chain
        assert pc.evict_until(alloc, need=6) == 1
        assert pc.lookup(np.arange(9, dtype=np.int32)) == held[:2]

    def test_eviction_skips_referenced_entries(self):
        alloc = BlockAllocator(4)
        pc = PrefixCache(2)
        held = self._published(alloc, pc, _tok(1, 2, 3, 4), rid=1)
        alloc.reserve(2, 1)
        alloc.attach(2, held[:2])                   # live request shares
        assert pc.evict_until(alloc, need=4) == 0   # nothing evictable
        alloc.release(2)
        assert pc.evict_until(alloc, need=4) == 2   # now it drains


# ---------------------------------------------------------------------------
# Serving-metrics satellite regressions.
# ---------------------------------------------------------------------------
class TestMetricsRegressions:
    def test_summarize_zero_wall_is_strict_json(self):
        """wall_s == 0 used to yield tok_per_s = inf -> json.dump writes
        bare ``Infinity`` -> invalid JSON for strict parsers."""
        r = Request(rid=0, prompt=np.zeros((4,), np.int32), max_new=4)
        r.tokens = [1, 2]
        stats = summarize([r], wall_s=0.0)
        assert stats["tok_per_s"] == 0.0
        strict = lambda c: (_ for _ in ()).throw(
            ValueError(f"non-finite constant {c}"))
        json.loads(json.dumps(stats), parse_constant=strict)

    def test_poisson_trace_rejects_nonpositive_rate(self):
        for bad in (0, 0.0, -3.0):
            with pytest.raises(ValueError, match="rate_rps must be > 0"):
                poisson_trace(4, rate_rps=bad, prompt_len=4, max_new=4,
                              vocab_size=16)

    def test_poisson_trace_shared_prefix(self):
        tr = poisson_trace(6, rate_rps=10, prompt_len=8, max_new=4,
                           vocab_size=64, shared_prefix_len=32, seed=1)
        assert all(len(r.prompt) == 40 for r in tr)
        head = tr[0].prompt[:32]
        assert all((r.prompt[:32] == head).all() for r in tr)
        tails = {tuple(r.prompt[32:]) for r in tr}
        assert len(tails) > 1                       # unique tails

    def test_mesh_spec_malformed_message(self):
        for bad in ("8", "2x2x2", "axb", "4x"):
            with pytest.raises(ValueError, match="DATAxMODEL"):
                parse_mesh_spec(bad)
        assert parse_mesh_spec(None) is None
        assert parse_mesh_spec("1x1") is None


# ---------------------------------------------------------------------------
# End-to-end shared-prefix serving (reduced qwen3-8b).
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def qwen_setup():
    cfg = reduced_config(get_config("qwen3-8b"))
    model = build_model(cfg)
    params = model.quantize(model.init(jax.random.PRNGKey(0)), 8)
    cfg = dataclasses.replace(cfg, quant_mode="psi8")
    return cfg, params


def _shared_trace(cfg, n=8, prefix_len=64, tail_len=8, seed=0):
    rng = np.random.default_rng(seed)
    shared = rng.integers(0, cfg.vocab_size, size=(prefix_len,)) \
        .astype(np.int32)
    reqs = []
    for i in range(n):
        tail = rng.integers(0, cfg.vocab_size, size=(tail_len,)) \
            .astype(np.int32)
        reqs.append(Request(rid=i, prompt=np.concatenate([shared, tail]),
                            max_new=2 + i % 4, arrival_s=0.001 * i))
    return reqs


class TestPrefixServing:
    def test_token_identical_on_vs_off_with_measured_hits(self, qwen_setup):
        """Acceptance: a 64-token shared prefix / 8-token unique tails
        trace serves token-identically with the prefix cache on vs off,
        with hit rate > 0, strictly fewer mean prefilled tokens, the
        decode step still compiling exactly once, and the allocator (LRU
        drained) back to its initial free count."""
        cfg, params = qwen_setup
        off = Server(cfg, params, max_batch=2, max_seq=96)
        on = Server(dataclasses.replace(cfg, prefix_cache=True), params,
                    max_batch=2, max_seq=96)
        assert on.prefix_enabled and not off.prefix_enabled
        done_off, stat_off = off.serve(_shared_trace(cfg), continuous=True)
        done_on, stat_on = on.serve(_shared_trace(cfg), continuous=True)
        toks = lambda done: {r.rid: tuple(r.tokens) for r in done}
        assert toks(done_off) == toks(done_on)
        pc = stat_on["prefix_cache"]
        assert pc["hit_rate"] > 0 and pc["hits"] > 0
        assert stat_on["prefix_tokens_reused"] > 0
        assert (stat_on["prefilled_tokens_mean"]
                < stat_off["prefilled_tokens_mean"])
        assert stat_on["decode_compiles"] == 1
        assert stat_off["decode_compiles"] == 1
        assert stat_on["blocks_free_end"] == stat_on["n_blocks"]

    def test_prefix_cache_requires_paged_and_rope(self, qwen_setup):
        cfg, params = qwen_setup
        dense = dataclasses.replace(cfg, cache_layout="dense",
                                    prefix_cache=True)
        with pytest.raises(ValueError, match="paged"):
            Server(dense, params, max_batch=2, max_seq=64)
        with pytest.raises(ValueError, match="RoPE"):
            dataclasses.replace(cfg, rope="sinusoidal",
                                prefix_cache=True).prefix_cache_enabled

    def test_static_mode_token_identical(self, qwen_setup):
        """Batch-synchronous scheduling under the prefix cache stays
        token-identical to continuous (and to prefix-off)."""
        cfg, params = qwen_setup
        on = Server(dataclasses.replace(cfg, prefix_cache=True), params,
                    max_batch=2, max_seq=96)
        done_c, _ = on.serve(_shared_trace(cfg, n=6), continuous=True)
        done_s, stat_s = on.serve(_shared_trace(cfg, n=6), continuous=False)
        toks = lambda done: {r.rid: tuple(r.tokens) for r in done}
        assert toks(done_c) == toks(done_s)
        assert stat_s["blocks_free_end"] == stat_s["n_blocks"]

    @pytest.mark.skipif(len(jax.devices()) < 8,
                        reason="needs 8 devices (CI distributed leg forces "
                               "--xla_force_host_platform_device_count=8)")
    def test_sharded_mesh_token_identical(self, qwen_setup):
        """Prefix-cached serving on a (4,2) mesh (slots and blocks
        partitioned over the data axis, shared blocks gathered across
        shards for the suffix prefill) emits exactly the single-device
        tokens, decode still compiling once."""
        from repro.launch.serve import parse_mesh_spec
        cfg, params = qwen_setup
        pcfg = dataclasses.replace(cfg, prefix_cache=True)
        single = Server(pcfg, params, max_batch=4, max_seq=96)
        meshed = Server(pcfg, params, max_batch=4, max_seq=96,
                        mesh=parse_mesh_spec("4x2"))
        d1, _ = single.serve(_shared_trace(cfg, n=8), continuous=True)
        d8, s8 = meshed.serve(_shared_trace(cfg, n=8), continuous=True)
        toks = lambda done: {r.rid: tuple(r.tokens) for r in done}
        assert toks(d1) == toks(d8)
        assert s8["prefix_cache"]["hit_rate"] > 0
        assert s8["decode_compiles"] == 1
        assert s8["slot_shards"] == 4
        assert s8["blocks_free_end"] == s8["n_blocks"]

    def test_bucket_misaligned_block_size(self, qwen_setup):
        """Regression: with block_size=8 (not a multiple of the 16-token
        prefill bucket) a 9-block hit put pos0=72 off the bucket grid and
        the suffix bucket over-allocated past the admission reservation
        ('allocating beyond its reservation' mid-serve).  Hits are now
        trimmed to the bucket grid (PrefixCache align_tokens), and output
        stays token-identical to prefix-off."""
        cfg, params = qwen_setup
        cfg8 = dataclasses.replace(cfg, cache_block_size=8)
        rng = np.random.default_rng(0)
        shared = rng.integers(0, cfg8.vocab_size, size=(72,)) \
            .astype(np.int32)

        def mk():
            r2 = np.random.default_rng(3)
            return [Request(rid=i, prompt=np.concatenate(
                        [shared, r2.integers(0, cfg8.vocab_size, size=(4,))
                         .astype(np.int32)]),
                        max_new=1, arrival_s=0.001 * i) for i in range(4)]

        off = Server(cfg8, params, max_batch=2, max_seq=96)
        on = Server(dataclasses.replace(cfg8, prefix_cache=True), params,
                    max_batch=2, max_seq=96)
        d_off, _ = off.serve(mk(), continuous=True)
        on.warmup(mk(), verbose=False)
        n0 = on.executor.prefill_cache_sizes()["prefill_insert_prefix"]
        d_on, s_on = on.serve(mk(), continuous=True, warmup=False)
        # warmup's deepest-hit depth mirrors the cache's alignment trim,
        # so the serve itself compiles no new prefix-prefill shapes
        n1 = on.executor.prefill_cache_sizes()["prefill_insert_prefix"]
        if n0 != -1:
            assert n1 == n0
        toks = lambda done: {r.rid: tuple(r.tokens) for r in done}
        assert toks(d_off) == toks(d_on)
        assert s_on["prefix_cache"]["hit_rate"] > 0
        # hit depth trimmed to the bucket grid: 8 blocks = 64 tokens, not 9
        assert s_on["prefix_cache"]["tokens_reused"] % 16 == 0
        assert s_on["blocks_free_end"] == s_on["n_blocks"]

    def test_eviction_pressure_under_distinct_prompts(self, qwen_setup):
        """DISTINCT 72-token prompts through a pool barely larger than one
        request's worst case: every retirement publishes 4 blocks the next
        admission cannot share, so the LRU must evict under reservation
        pressure; all requests still complete and the end state is
        leak-free."""
        cfg, params = qwen_setup
        rng = np.random.default_rng(7)
        reqs = [Request(rid=i,
                        prompt=rng.integers(0, cfg.vocab_size, size=(72,))
                        .astype(np.int32),
                        max_new=3, arrival_s=0.001 * i) for i in range(5)]
        on = Server(dataclasses.replace(cfg, prefix_cache=True), params,
                    max_batch=2, max_seq=96, n_blocks=7)
        done, stats = on.serve(reqs, continuous=True)
        assert stats["n_requests"] == 5
        assert all(len(r.tokens) == r.max_new for r in done)
        assert stats["prefix_cache"]["evicted_blocks"] > 0
        assert stats["prefix_cache"]["hit_rate"] == 0.0
        assert stats["blocks_free_end"] == 7
