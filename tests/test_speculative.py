"""Self-speculative decoding tests (DESIGN.md §"Self-speculative
decoding"): greedy acceptance must keep the served token streams
bit-identical to plain decode for EVERY (draft_bits, k) and every cache
combination — the draft pass is an optimization, never a semantics
change.  Covers the fuzz matrix over k x draft_bits, the int8-KV and
prefix-cache compositions, an adversarial zero-acceptance draft, the
compile-count contract, the remesh regression, and the summarize
accounting satellites."""
import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced_config
from repro.core.quantizer import fake_quant_param_tree
from repro.launch.scheduler import Request, summarize
from repro.launch.serve import Server, parse_spec_spec
from repro.models import build_model

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(snippet: str, devices: int = 8, timeout: int = 480) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(snippet)],
                         capture_output=True, text=True, env=env,
                         timeout=timeout, cwd=REPO)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


@pytest.fixture(scope="module")
def qwen_setup():
    """Reduced qwen3-8b, QAT-preconditioned at 3 bits before the psi8
    serving quantization, so low-bit draft views actually agree with the
    target often enough to exercise the multi-accept emit path (random
    init accepts ~0 and would only ever cover the a=0 branch)."""
    cfg = reduced_config(get_config("qwen3-8b"))
    model = build_model(cfg)
    params = fake_quant_param_tree(model.init(jax.random.PRNGKey(0)), 3)
    params = model.quantize(params, 8)
    cfg = dataclasses.replace(cfg, quant_mode="psi8")
    return cfg, params


def _trace(cfg, seed=0, n=4, budgets=(4, 7, 3, 6)):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        size=(5 + 3 * i,)).astype(np.int32),
                    max_new=budgets[i % len(budgets)], arrival_s=0.001 * i)
            for i in range(n)]


def _toks(done):
    return {r.rid: tuple(r.tokens) for r in done}


@pytest.fixture(scope="module")
def baseline(qwen_setup):
    """Plain-decode tokens for the shared trace: the oracle every
    speculative configuration must reproduce exactly."""
    cfg, params = qwen_setup
    server = Server(cfg, params, max_batch=2, max_seq=64)
    done, stats = server.serve(_trace(cfg), continuous=True)
    assert stats["decode_compiles"] == 1
    return _toks(done)


class TestSpecTokenIdentity:
    @pytest.mark.parametrize("dbits", [2, 3])
    @pytest.mark.parametrize("k", [2, 4, 8])
    def test_fuzz_matrix_identical_to_plain_decode(self, qwen_setup,
                                                   baseline, dbits, k):
        """Acceptance fuzz: every (draft_bits, k) cell serves the shared
        trace token-identically to plain decode, compiles exactly the
        draft+verify pair (and NO plain decode shape), and returns every
        pool block."""
        cfg, params = qwen_setup
        server = Server(cfg, params, max_batch=2, max_seq=64,
                        speculative=(dbits, k))
        done, stats = server.serve(_trace(cfg), continuous=True)
        assert _toks(done) == baseline
        sp = stats["speculative"]
        assert sp["spec_compiles"] == {"draft": 1, "verify": 1, "decode": 0}
        assert (sp["draft_bits"], sp["k"]) == (dbits, k)
        assert sp["rounds"] > 0 and sp["accepted_draft_tokens"] >= 0
        assert stats["blocks_free_end"] == stats["n_blocks"]

    def test_static_mode_identical(self, qwen_setup, baseline):
        """Batch-synchronous scheduling under speculation stays identical
        too — rounds are per-step, not per-policy."""
        cfg, params = qwen_setup
        server = Server(cfg, params, max_batch=2, max_seq=64,
                        speculative=(3, 4))
        done, _ = server.serve(_trace(cfg), continuous=False)
        assert _toks(done) == baseline

    def test_int8_kv_identical(self):
        """Speculation over the quantized KV pool: draft writes, verify
        re-scatters, and the stale rejected tail all round-trip through
        the int8 scale pools without diverging from plain decode."""
        cfg = reduced_config(get_config("qwen3-8b"), kv_quant="int8")
        model = build_model(cfg)
        params = fake_quant_param_tree(model.init(jax.random.PRNGKey(0)), 3)
        params = model.quantize(params, 8)
        cfg = dataclasses.replace(cfg, quant_mode="psi8")
        plain = Server(cfg, params, max_batch=2, max_seq=64)
        spec = Server(cfg, params, max_batch=2, max_seq=64,
                      speculative=(3, 4))
        done_p, _ = plain.serve(_trace(cfg, seed=1), continuous=True)
        done_s, stats = spec.serve(_trace(cfg, seed=1), continuous=True)
        assert _toks(done_p) == _toks(done_s)
        assert stats["blocks_free_end"] == stats["n_blocks"]

    def test_prefix_cache_composition(self, qwen_setup):
        """Speculation + shared-prefix reuse: spec-on serves a shared-
        prefix trace identically to spec-off (both prefix-on), still with
        measured hits and an LRU-drained allocator."""
        cfg, params = qwen_setup
        cfg = dataclasses.replace(cfg, prefix_cache=True)
        rng = np.random.default_rng(3)
        shared = rng.integers(0, cfg.vocab_size, size=(32,)).astype(np.int32)

        def mk():
            reqs = []
            for i in range(5):
                tail = rng.integers(0, cfg.vocab_size, size=(4,)) \
                    .astype(np.int32)
                reqs.append(Request(rid=i,
                                    prompt=np.concatenate([shared, tail]),
                                    max_new=3 + i % 4, arrival_s=0.001 * i))
            return reqs

        trace = mk()
        clone = lambda: [dataclasses.replace(r, tokens=[]) for r in trace]
        off = Server(cfg, params, max_batch=2, max_seq=96)
        on = Server(cfg, params, max_batch=2, max_seq=96,
                    speculative=(3, 4))
        assert off.prefix_enabled and on.prefix_enabled
        done_off, _ = off.serve(clone(), continuous=True)
        done_on, stats = on.serve(clone(), continuous=True)
        assert _toks(done_off) == _toks(done_on)
        assert stats["prefix_cache"]["hits"] > 0
        assert stats["blocks_free_end"] == stats["n_blocks"]

    def test_adversarial_draft_degrades_to_plain_decode(self, qwen_setup,
                                                        baseline):
        """Forced-zero acceptance: a draft pass that returns token id -1
        (never a valid argmax) must reject at position 0 every round, so
        the engine emits exactly one verified token per round — the plain-
        decode stream — while the corrupted drafts' stale KV writes are
        overwritten before any later read, and no block leaks."""
        cfg, params = qwen_setup
        server = Server(cfg, params, max_batch=2, max_seq=64,
                        speculative=(3, 4))
        real_draft = server.executor.draft

        def hostile_draft(token, pos, active, cache, block_table):
            drafts, cache = real_draft(token, pos, active, cache,
                                       block_table)
            return jnp.full_like(drafts, -1), cache

        server.executor.draft = hostile_draft
        done, stats = server.serve(_trace(cfg), continuous=True)
        sp = stats["speculative"]
        assert _toks(done) == baseline
        assert sp["accepted_draft_tokens"] == 0
        assert sp["mean_accepted"] == 0.0
        assert stats["accepted_per_step"] == 0.0
        assert stats["blocks_free_end"] == stats["n_blocks"]


class TestSpecConstruction:
    def test_parse_spec_spec(self):
        assert parse_spec_spec(None) is None
        assert parse_spec_spec("off") is None
        assert parse_spec_spec("3:4") == (3, 4)
        assert parse_spec_spec("2:8") == (2, 8)
        with pytest.raises(ValueError):
            parse_spec_spec("3")
        with pytest.raises(ValueError):
            parse_spec_spec("3:0")

    def test_requires_paged_layout(self, qwen_setup):
        cfg, params = qwen_setup
        dense = dataclasses.replace(cfg, cache_layout="dense")
        with pytest.raises(ValueError, match="paged"):
            Server(dense, params, max_batch=2, max_seq=64,
                   speculative=(3, 4))

    def test_k_bounded_by_block_size(self, qwen_setup):
        cfg, params = qwen_setup
        with pytest.raises(ValueError, match="block"):
            Server(cfg, params, max_batch=2, max_seq=64,
                   speculative=(3, cfg.cache_block_size + 1))

    def test_requires_quantized_params(self):
        """A float checkpoint has no stored codes to derive a draft view
        from — constructing a speculative engine on it must fail loudly."""
        cfg = reduced_config(get_config("qwen3-8b"))
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))    # float, unquantized
        with pytest.raises(ValueError, match="[Qq]uantized"):
            Server(cfg, params, max_batch=2, max_seq=64,
                   speculative=(3, 4))

    def test_single_device_remesh_is_noop(self, qwen_setup):
        cfg, params = qwen_setup
        server = Server(cfg, params, max_batch=2, max_seq=64,
                        speculative=(3, 4))
        assert server.executor.remesh() is server.executor


class TestSpecAccounting:
    def test_summarize_zero_finished_is_strict_json(self):
        stats = summarize([], wall_s=1.0)
        assert stats["accepted_per_step"] == 0.0
        assert stats["draft_overhead_s"] == 0.0

    def test_summarize_skips_nonspeculative_requests(self):
        """Requests that never ran a speculative round report NaN
        accepted_per_step and must be skipped, not averaged as zero."""
        reqs = []
        for i, (rounds, accepted) in enumerate([(0, 0), (4, 12), (2, 2)]):
            r = Request(rid=i, prompt=np.zeros((4,), np.int32), max_new=4,
                        arrival_s=0.0)
            r.admit_s, r.first_token_s, r.finish_s = 0.1, 0.2, 1.0
            r.tokens = [1, 2]
            r.spec_rounds, r.spec_accepted = rounds, accepted
            r.draft_s = 0.25
            reqs.append(r)
        assert np.isnan(reqs[0].accepted_per_step)
        stats = summarize(reqs, wall_s=2.0)
        assert stats["accepted_per_step"] == pytest.approx(2.0)  # (3+1)/2
        assert stats["draft_overhead_s"] == pytest.approx(0.75)

    def test_all_nonspeculative_degrades_to_zero(self):
        r = Request(rid=0, prompt=np.zeros((4,), np.int32), max_new=4,
                    arrival_s=0.0)
        r.admit_s, r.first_token_s, r.finish_s = 0.1, 0.2, 1.0
        r.tokens = [1]
        stats = summarize([r], wall_s=1.0)
        assert stats["accepted_per_step"] == 0.0
        assert stats["draft_overhead_s"] == 0.0


# ---------------------------------------------------------------------------
# Multi-device: forced 8-CPU subprocesses (same pattern as
# test_distributed.py — the device-count flag must not leak in-process).
# ---------------------------------------------------------------------------
def test_spec_sharded_tokens_identical():
    """Speculative serving on a forced 8-device (4, 2) mesh is token-
    identical to the single-device SPEC engine and to plain decode, with
    the same draft+verify-only compile contract."""
    out = _run("""
        import dataclasses, jax, numpy as np
        from repro.configs import get_config, reduced_config
        from repro.core.quantizer import fake_quant_param_tree
        from repro.launch.mesh import make_mesh
        from repro.launch.scheduler import Request
        from repro.launch.serve import Server
        from repro.models import build_model

        cfg = reduced_config(get_config("qwen3-8b"))
        model = build_model(cfg)
        params = fake_quant_param_tree(model.init(jax.random.PRNGKey(0)), 3)
        params = model.quantize(params, 8)
        cfg = dataclasses.replace(cfg, quant_mode="psi8")
        rng = np.random.default_rng(0)
        prompts = [rng.integers(0, cfg.vocab_size, size=(6 + 2 * i,))
                   .astype(np.int32) for i in range(6)]
        def mk():
            return [Request(rid=i, prompt=prompts[i], max_new=mn,
                            arrival_s=0.0)
                    for i, mn in enumerate([3, 7, 2, 5, 4, 6])]
        toks = lambda done: {r.rid: tuple(r.tokens) for r in done}

        plain = Server(cfg, params, max_batch=4, max_seq=64)
        base = toks(plain.serve(mk(), continuous=True)[0])
        s1 = Server(cfg, params, max_batch=4, max_seq=64,
                    speculative=(3, 4))
        d1, st1 = s1.serve(mk(), continuous=True)
        s8 = Server(cfg, params, max_batch=4, max_seq=64,
                    speculative=(3, 4),
                    mesh=make_mesh((4, 2), ("data", "model")))
        d8, st8 = s8.serve(mk(), continuous=True)
        assert st8["slot_shards"] == 4
        assert toks(d1) == base, "spec 1x1 diverged from plain"
        assert toks(d8) == base, "spec (4,2) diverged from plain"
        for st in (st1, st8):
            assert st["speculative"]["spec_compiles"] == \\
                {"draft": 1, "verify": 1, "decode": 0}
        print("OK", st8["slot_shards"])
    """)
    assert "OK 4" in out


def test_remesh_preserves_spec_and_pool_then_serves():
    """Satellite regression (PR 7): remesh must rebuild with the FULL
    construction config.  An executor built with a custom n_blocks and a
    speculative pair, remeshed onto a survivor subset, must carry both
    through — and a Server running on the remeshed executor must still
    serve token-identically to plain decode."""
    out = _run("""
        import dataclasses, jax, numpy as np
        from repro.configs import get_config, reduced_config
        from repro.core.quantizer import fake_quant_param_tree
        from repro.launch.mesh import make_mesh
        from repro.launch.scheduler import Request
        from repro.launch.serve import Server
        from repro.models import build_model
        from repro.runtime.executor import Executor

        cfg = reduced_config(get_config("qwen3-8b"))
        model = build_model(cfg)
        params = fake_quant_param_tree(model.init(jax.random.PRNGKey(0)), 3)
        params = model.quantize(params, 8)
        cfg = dataclasses.replace(cfg, quant_mode="psi8")
        rng = np.random.default_rng(0)
        prompts = [rng.integers(0, cfg.vocab_size, size=(8,))
                   .astype(np.int32) for _ in range(4)]
        def mk():
            return [Request(rid=i, prompt=prompts[i], max_new=mn,
                            arrival_s=0.0)
                    for i, mn in enumerate([3, 6, 4, 5])]
        toks = lambda done: {r.rid: tuple(r.tokens) for r in done}

        base = toks(Server(cfg, params, max_batch=2, max_seq=64)
                    .serve(mk(), continuous=True)[0])

        ex = Executor(cfg, params, max_batch=2, max_seq=64,
                      mesh=make_mesh((4, 2), ("data", "model")),
                      n_blocks=10, speculative=(3, 4))
        ex2 = ex.remesh(jax.devices()[:4], model_parallel=2)
        assert ex2 is not ex
        assert ex2.mesh.devices.size == 4, ex2.mesh.devices.shape
        # the PR 7 regression: these were silently dropped on rebuild
        assert ex2.n_blocks == ex.n_blocks == 10, ex2.n_blocks
        assert ex2.speculative == (3, 4), ex2.speculative

        server = Server(cfg, params, max_batch=2, max_seq=64,
                        executor=ex2, speculative=(3, 4))
        done, stats = server.serve(mk(), continuous=True)
        assert toks(done) == base, "remeshed spec engine diverged"
        assert stats["speculative"]["spec_compiles"] == \\
            {"draft": 1, "verify": 1, "decode": 0}
        print("OK remesh", ex2.n_blocks)
    """)
    assert "OK remesh 10" in out
