"""Substrate: data pipeline, optimizer, gradient compression, checkpointing,
straggler monitor, elastic re-mesh planning."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpoint import CheckpointManager
from repro.data.pipeline import TokenStream, synthetic_mnist
from repro.optim import adamw, cosine_schedule, sgd
from repro.optim.compress import (compress_gradients, compressed_bytes,
                                  decompress_gradients)
from repro.runtime.elastic import plan_remesh
from repro.runtime.straggler import StragglerMonitor


class TestDataPipeline:
    def test_deterministic(self):
        a = next(TokenStream(1000, 32, 8, seed=7))
        b = next(TokenStream(1000, 32, 8, seed=7))
        assert np.array_equal(a, b)

    def test_resume_exact(self):
        s1 = TokenStream(1000, 32, 8, seed=7)
        for _ in range(5):
            next(s1)
        state = s1.state_dict()
        want = next(s1)
        s2 = TokenStream(1000, 32, 8)
        s2.load_state_dict(state)
        assert np.array_equal(next(s2), want)

    def test_host_sharding_partitions_batch(self):
        full = next(TokenStream(1000, 16, 8, seed=3, host_id=0, num_hosts=1))
        h0 = next(TokenStream(1000, 16, 8, seed=3, host_id=0, num_hosts=2))
        h1 = next(TokenStream(1000, 16, 8, seed=3, host_id=1, num_hosts=2))
        assert h0.shape == (4, 16) and h1.shape == (4, 16)
        assert not np.array_equal(h0, h1)
        assert full.shape == (8, 16)

    def test_synthetic_mnist_learnable_structure(self):
        xs, ys = synthetic_mnist(256, seed=0)
        assert xs.shape == (256, 32, 32, 1)
        assert set(np.unique(ys)) <= set(range(10))
        # same-class images are more similar than cross-class ones
        d0 = xs[ys == ys[0]]
        other = xs[ys != ys[0]]
        assert (np.mean([np.linalg.norm(a - d0[0]) for a in d0[1:4]])
                < np.mean([np.linalg.norm(a - d0[0]) for a in other[:4]]))


class TestOptim:
    def _quad(self, opt, steps=60):
        params = {"w": jnp.asarray([3.0, -2.0])}
        state = opt.init(params)
        for _ in range(steps):
            g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
            params, state, _ = opt.update(g, state, params)
        return float(jnp.abs(params["w"]).max())

    def test_adamw_converges(self):
        assert self._quad(adamw(lr=0.1, weight_decay=0.0)) < 0.3

    def test_sgd_converges(self):
        assert self._quad(sgd(lr=0.05, momentum=0.5)) < 0.3

    def test_cosine_schedule(self):
        lr = cosine_schedule(1.0, warmup=10, total=100)
        assert float(lr(0)) == 0.0
        assert float(lr(10)) == pytest.approx(1.0)
        assert float(lr(100)) == pytest.approx(0.0, abs=1e-6)

    def test_clip_norm_applied(self):
        opt = adamw(clip_norm=1.0)
        p = {"w": jnp.zeros((3,))}
        s = opt.init(p)
        _, _, m = opt.update({"w": jnp.full((3,), 100.0)}, s, p)
        assert float(m["grad_norm"]) > 1.0


class TestGradCompression:
    @given(st.integers(0, 10))
    @settings(max_examples=10, deadline=None)
    def test_roundtrip_error_bounded(self, seed):
        rng = np.random.default_rng(seed)
        g = {"a": jnp.asarray(rng.normal(size=(64,)).astype(np.float32))}
        comp, err = compress_gradients(g)
        rec = decompress_gradients(comp)
        scale = float(comp["a"]["scale"])
        assert float(jnp.abs(rec["a"] - g["a"]).max()) <= scale * 0.5 + 1e-7

    def test_error_feedback_unbiased_over_steps(self):
        """Constant gradient: error feedback makes the mean reconstructed
        gradient converge to the true one."""
        g = {"a": jnp.asarray(np.linspace(-1e-3, 1e-3, 32), dtype=jnp.float32)}
        err = None
        acc = jnp.zeros((32,))
        for _ in range(64):
            comp, err = compress_gradients(g, err)
            acc = acc + decompress_gradients(comp)["a"]
        np.testing.assert_allclose(np.asarray(acc / 64), np.asarray(g["a"]),
                                   atol=2e-6)

    def test_payload_4x_smaller(self):
        g = {"a": jnp.zeros((1024,), jnp.float32)}
        comp, _ = compress_gradients(g)
        assert compressed_bytes(comp) * 3 < 1024 * 4


class TestCheckpoint:
    def test_save_restore_roundtrip(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2)
        tree = {"params": {"w": jnp.arange(6.0).reshape(2, 3)},
                "opt": {"m": [jnp.ones((2,)), jnp.zeros((1,))],
                        "step": jnp.asarray(5)}}
        mgr.save(10, tree, extra={"data": {"step": 10, "seed": 0}})
        got, extra = mgr.restore()
        assert extra["data"]["step"] == 10
        np.testing.assert_array_equal(np.asarray(got["params"]["w"]),
                                      np.arange(6.0).reshape(2, 3))
        np.testing.assert_array_equal(np.asarray(got["opt"]["m"][0]),
                                      np.ones((2,)))

    def test_keep_k_rotation(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2)
        for s in (1, 2, 3, 4):
            mgr.save(s, {"x": jnp.asarray([s])})
        assert mgr.all_steps() == [3, 4]

    def test_keep_every_protects(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=1, keep_every=2)
        for s in (1, 2, 3):
            mgr.save(s, {"x": jnp.asarray([s])})
        assert 2 in mgr.all_steps()

    def test_async_save(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(1, {"x": jnp.ones((128, 128))}, blocking=False)
        mgr.wait()
        got, _ = mgr.restore(1)
        assert got["x"].shape == (128, 128)

    def test_atomic_no_partial(self, tmp_path):
        """tmp dirs never count as checkpoints."""
        mgr = CheckpointManager(str(tmp_path))
        os.makedirs(tmp_path / "tmp.99", exist_ok=True)
        assert mgr.all_steps() == []

    def test_restore_with_shardings_resharding(self, tmp_path):
        """Elastic path: restore device_puts with the current sharding."""
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(1, {"w": jnp.arange(16.0)})
        sharding = jax.sharding.SingleDeviceSharding(jax.devices()[0])
        got, _ = mgr.restore(1, shardings={"w": sharding})
        assert got["w"].sharding == sharding


class TestStragglerElastic:
    def test_straggler_flagging(self):
        mon = StragglerMonitor(n_hosts=4, patience=2)
        for _ in range(3):
            rep = mon.observe([1.0, 1.0, 1.0, 2.0])
        assert rep["flagged_hosts"] == [3]
        assert rep["evict_recommended"]
        w = mon.input_weights()
        assert w[3] < w[0]

    def test_no_false_positives(self):
        mon = StragglerMonitor(n_hosts=4)
        for _ in range(10):
            rep = mon.observe([1.0, 1.01, 0.99, 1.02])
        assert not rep["flagged_hosts"]

    def test_plan_remesh_shrinks_data_axis(self):
        plan = plan_remesh(240, model_parallel=16)
        assert plan.shape == (15, 16)
        assert plan.dropped_devices == 0
        plan2 = plan_remesh(250, model_parallel=16)
        assert plan2.shape == (15, 16) and plan2.dropped_devices == 10

    def test_plan_remesh_multi_pod(self):
        plan = plan_remesh(512, model_parallel=16, pods=2)
        assert plan.shape == (2, 16, 16)

    def test_plan_remesh_rejects_sub_tp(self):
        with pytest.raises(ValueError):
            plan_remesh(8, model_parallel=16)
