"""Core PSI quantization: exhaustive Table-I validation + properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import psi


class TestTable1:
    """Paper Table I: multiplication error per number of partitions."""

    def test_int5_2psi_error_set(self):
        """INT5 with 2 PSIs errs ONLY at w in {+-11, +-13}."""
        w = np.arange(-16, 16)
        vals = np.asarray(psi.psi_value_table(5))
        bad = w[vals != w]
        assert set(bad.tolist()) == {-13, -11, 11, 13}

    def test_int5_worst_case_error_is_9pct(self):
        w = np.arange(-16, 16)
        vals = np.asarray(psi.psi_value_table(5))
        rel = np.abs(vals - w) / np.maximum(np.abs(w), 1)
        assert abs(rel.max() - 1 / 11) < 1e-9          # ~9 % (paper)

    def test_int8_4psi_exact(self):
        """INT8 with 4 PSIs is exact for all of [-128, 127]."""
        w = np.arange(-128, 128)
        assert np.array_equal(np.asarray(psi.psi_value_table(8)), w)

    def test_psi_term_budget(self):
        """<= 2 terms for INT5, <= 4 for INT8 (the hardware register count)."""
        for bits, n in ((5, 2), (8, 4)):
            tab = psi._best_decomposition_table(bits)
            nz = (tab[:, 0::2] != 0).sum(axis=1)
            assert nz.max() <= n


class TestDecomposeReconstruct:
    @pytest.mark.parametrize("bits", [5, 8])
    def test_roundtrip_matches_value_table(self, bits):
        lo = -16 if bits == 5 else -128
        hi = 16 if bits == 5 else 128
        w = jnp.arange(lo, hi)
        s, n = psi.psi_decompose_int(w, bits)
        rec = psi.psi_reconstruct(s, n)
        assert np.array_equal(np.asarray(rec),
                              np.asarray(psi.psi_value_table(bits)))

    @given(st.integers(-128, 127), st.integers(-128, 127))
    @settings(max_examples=200, deadline=None)
    def test_sam_multiply_exact_int8(self, w, x):
        """SAM (mux + barrel shift + accumulate) == integer multiply."""
        s, n = psi.psi_decompose_int(jnp.asarray([w]), 8)
        got = psi.sam_multiply(jnp.asarray([x]), s, n)
        assert int(got[0]) == w * x

    @given(st.integers(-16, 15), st.integers(-128, 127))
    @settings(max_examples=200, deadline=None)
    def test_sam_multiply_int5_error_bound(self, w, x):
        s, n = psi.psi_decompose_int(jnp.asarray([w]), 5)
        got = int(psi.sam_multiply(jnp.asarray([x]), s, n)[0])
        assert abs(got - w * x) <= abs(x)  # |w' - w| <= 1

    def test_int5_multiplication_error_exhaustive(self):
        """All (w, X) pairs: errors appear only at the Table-I weights."""
        w = np.arange(-16, 16)
        x = np.arange(-128, 128)
        wp = np.asarray(psi.psi_value_table(5))
        prod_hw = wp[:, None] * x[None, :]
        prod = w[:, None] * x[None, :]
        err_rows = np.unique(w[np.any(prod_hw != prod, axis=1)])
        assert set(err_rows.tolist()) <= {-13, -11, 11, 13}


class TestMOA:
    """Appendix: sign-extension == 2's complement of the negative count."""

    @given(st.lists(st.integers(-16, 15), min_size=1, max_size=18))
    @settings(max_examples=200, deadline=None)
    def test_moa_sign_trick(self, ops):
        arr = jnp.asarray(ops)[:, None]
        got = psi.moa_sign_extension_sum(arr, in_bits=5, out_bits=18)
        assert int(got[0]) == sum(ops)

    def test_moa18_capacity(self):
        """18 operands of 18-PSI range fit the 18-bit MOA output."""
        rng = np.random.default_rng(0)
        ops = rng.integers(-(2 ** 12), 2 ** 12, size=(18, 64))
        got = psi.moa_sign_extension_sum(jnp.asarray(ops), 13, 18)
        assert np.array_equal(np.asarray(got), ops.sum(0))


class TestFormatRegistry:
    """PsiFormat registry: every registered width's decomposition meets its
    certified metadata; QuantizedTensor round-trips as a pytree."""

    def test_registered_widths_cover_sub_byte_range(self):
        assert set(psi.registered_bits()) == set(range(2, 9))

    @pytest.mark.parametrize("bits", sorted(psi.DEFAULT_N_PSI))
    def test_declared_error_bound_is_met(self, bits):
        """The value table's exhaustive error never exceeds the format's
        declared worst_case_rel_error (and `exact` means zero error)."""
        fmt = psi.get_format(bits)
        w = np.arange(fmt.w_min, fmt.w_max + 1)
        vals = np.asarray(fmt.value_table())
        rel = np.abs(vals - w) / np.maximum(np.abs(w), 1)
        assert rel.max() <= fmt.worst_case_rel_error + 1e-12
        assert fmt.exact == bool(np.array_equal(vals, w))

    def test_paper_table1_bounds(self):
        """INT8 exact, INT5 <= 9% worst case (paper Table I)."""
        assert psi.get_format(8).exact
        f5 = psi.get_format(5)
        assert not f5.exact
        assert abs(f5.worst_case_rel_error - 1 / 11) < 1e-12

    @pytest.mark.parametrize("bits", sorted(psi.DEFAULT_N_PSI))
    def test_error_monotone_in_psi_terms(self, bits):
        """More PSI terms never increase the worst-case error, and the
        budget n_psi+1 is at least as accurate as the registered one."""
        fmt = psi.get_format(bits)
        w = np.arange(fmt.w_min, fmt.w_max + 1)
        prev = None
        for n in range(1, fmt.n_psi + 2):
            vals = psi.psi_value_table(bits, n_psi=n)
            err = (np.abs(vals - w) / np.maximum(np.abs(w), 1)).max()
            if prev is not None:
                assert err <= prev + 1e-12, (bits, n)
            prev = err

    @pytest.mark.parametrize("packed", [False, True])
    def test_quantized_tensor_pytree_roundtrip(self, packed):
        rng = np.random.default_rng(7)
        w = jnp.asarray(rng.normal(size=(32, 16)).astype(np.float32))
        q = psi.quantize_weights(w, 5, axis=0)
        if packed:
            q = q.pack()
        leaves, treedef = jax.tree_util.tree_flatten(q)
        assert len(leaves) == 2          # (data, scale); fmt/packed static
        q2 = jax.tree_util.tree_unflatten(treedef, leaves)
        assert q2.fmt == q.fmt and q2.packed == q.packed
        assert np.array_equal(np.asarray(q2.codes), np.asarray(q.codes))
        # structure equality includes the static format metadata
        q3 = psi.quantize_weights(w, 8, axis=0)
        assert (jax.tree_util.tree_structure(q)
                != jax.tree_util.tree_structure(q3))

    @given(st.sampled_from([2, 3, 4, 5, 6, 7]), st.integers(1, 3))
    @settings(max_examples=30, deadline=None)
    def test_pack_unpack_roundtrip_any_width(self, bits, seed):
        fmt = psi.get_format(bits)
        rng = np.random.default_rng(seed)
        codes = rng.integers(fmt.w_min, fmt.w_max + 1,
                             size=(8 * seed, 16)).astype(np.int8)
        codes = np.asarray(psi.psi_project_int(jnp.asarray(codes), bits))
        packed = psi.pack_codes(jnp.asarray(codes), bits)
        assert packed.size == codes.size * bits / 8
        assert np.array_equal(
            np.asarray(psi.unpack_codes(packed, bits)), codes)

    def test_unknown_width_raises(self):
        with pytest.raises(ValueError):
            psi.get_format(9)
        with pytest.raises(ValueError):
            psi.get_format("int5")


class TestFloatQuant:
    def test_quantize_dequantize_error_bound(self):
        rng = np.random.default_rng(0)
        w = jnp.asarray(rng.normal(size=(64, 32)).astype(np.float32))
        for bits, tol in ((8, 0.02), (5, 0.15)):
            q = psi.quantize_weights(w, bits, axis=0)
            err = jnp.abs(q.dequantize() - w).max() / jnp.abs(w).max()
            assert float(err) < tol

    def test_codes_are_psi_representable(self):
        rng = np.random.default_rng(1)
        w = jnp.asarray(rng.normal(size=(40, 24)).astype(np.float32))
        q = psi.quantize_weights(w, 5, axis=0)
        codes = np.asarray(q.codes)
        valid = set(np.asarray(psi.psi_value_table(5)).tolist())
        assert set(np.unique(codes).tolist()) <= valid

    def test_ste_gradient_identity(self):
        w = jnp.ones((8, 8))
        g = jax.grad(lambda w: psi.fake_quant_ste(w, 8, (0,)).sum())(w)
        assert np.allclose(np.asarray(g), 1.0)

    @given(st.integers(1, 4))
    @settings(max_examples=20, deadline=None)
    def test_pack_unpack_roundtrip(self, seed):
        rng = np.random.default_rng(seed)
        codes = rng.integers(-16, 16, size=(8 * seed, 16)).astype(np.int8)
        codes = np.asarray(psi.psi_project_int(jnp.asarray(codes), 5))
        packed = psi.pack_int5(jnp.asarray(codes))
        assert packed.size == codes.size * 0.625
        assert np.array_equal(np.asarray(psi.unpack_int5(packed)), codes)

    def test_activation_quant_int8(self):
        x = jnp.asarray(np.random.default_rng(0).normal(size=(100,)) * 3)
        q, scale = psi.quantize_activations_int8(x)
        err = jnp.abs(q.astype(jnp.float32) * scale - x).max()
        assert float(err) <= float(scale) * 0.5 + 1e-6


class TestDraftView:
    """Self-speculative draft derivation (DESIGN.md §"Self-speculative
    decoding"): ``draft_view(b)`` rescales the STORED codes onto the
    narrower grid and must equal quantizing the dequantized weights
    directly to ``b`` bits — symmetric scales put the per-channel max |code|
    exactly at qmax, so the rescale is the same rounding problem."""

    @given(st.sampled_from([2, 3, 4, 5]), st.integers(1, 4))
    @settings(max_examples=25, deadline=None)
    def test_view_equals_direct_quantization(self, dbits, seed):
        rng = np.random.default_rng(seed)
        w = jnp.asarray(rng.normal(size=(16, 24)).astype(np.float32))
        q8 = psi.quantize_weights(w, 8, axis=(0,))
        view = q8.draft_view(dbits)
        direct = psi.quantize_weights(
            q8.dequantize(jnp.float32), dbits, axis=(0,))
        assert view.fmt.bits == dbits and not view.packed
        np.testing.assert_array_equal(np.asarray(view.codes),
                                      np.asarray(direct.codes))
        np.testing.assert_allclose(np.asarray(view.scale),
                                   np.asarray(direct.scale), rtol=1e-6)

    @pytest.mark.parametrize("dbits", [2, 3])
    def test_packed_view_dequantize_and_gather(self, dbits):
        """The packed sub-byte storage of a view is bit-identical to the
        packed direct quantization through BOTH read paths: full
        ``dequantize`` and the embedding-style ``gather_rows``."""
        rng = np.random.default_rng(0)
        w = jnp.asarray(rng.normal(size=(32, 24)).astype(np.float32))
        q8 = psi.quantize_weights(w, 8, axis=(1,))       # per-row scales
        view = q8.draft_view(dbits).pack()
        direct = psi.quantize_weights(
            q8.dequantize(jnp.float32), dbits, axis=(1,)).pack()
        assert view.packed and direct.packed
        assert view.data.dtype == direct.data.dtype == jnp.uint8
        np.testing.assert_array_equal(np.asarray(view.data),
                                      np.asarray(direct.data))
        np.testing.assert_allclose(
            np.asarray(view.dequantize(jnp.float32)),
            np.asarray(direct.dequantize(jnp.float32)), rtol=1e-6)
        ids = jnp.asarray([0, 3, 3, 31, 17])
        np.testing.assert_allclose(
            np.asarray(view.gather_rows(ids, jnp.float32)),
            np.asarray(direct.gather_rows(ids, jnp.float32)), rtol=1e-6)

    def test_packed_source_stays_packed(self):
        """A view extracted from a PACKED serving leaf comes back packed
        (the serving layout is preserved) and still equals the direct
        quantization of the dequantized weights."""
        rng = np.random.default_rng(2)
        w = jnp.asarray(rng.normal(size=(16, 8)).astype(np.float32))
        q5 = psi.quantize_weights(w, 5, axis=(0,)).pack()
        view = q5.draft_view(2)
        assert view.packed and view.fmt.bits == 2
        direct = psi.quantize_weights(
            q5.dequantize(jnp.float32), 2, axis=(0,))
        np.testing.assert_array_equal(np.asarray(view.codes),
                                      np.asarray(direct.codes))

    def test_view_degenerate_and_widening(self):
        rng = np.random.default_rng(3)
        w = jnp.asarray(rng.normal(size=(8, 8)).astype(np.float32))
        q3 = psi.quantize_weights(w, 3, axis=(0,))
        assert q3.draft_view(3) is q3          # same width: no-op
        with pytest.raises(ValueError, match="narrows only"):
            q3.draft_view(5)
