"""Continuous-batching serving engine tests: scheduler invariants (pure
host-side), engine-level slot reuse / EOS retirement, decode-step shape
stability (no recompiles), and the INT5 bit-plane round-trip property."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config, reduced_config
from repro.core import psi
from repro.launch.scheduler import (Request, Scheduler, SlotAllocator,
                                    poisson_trace)
from repro.launch.serve import Server
from repro.models import build_model


def _requests(specs):
    """specs: list of (arrival_s, max_new)."""
    rng = np.random.default_rng(0)
    return [Request(rid=i, prompt=rng.integers(0, 256, size=(8,))
                    .astype(np.int32), max_new=mn, arrival_s=at)
            for i, (at, mn) in enumerate(specs)]


# ---------------------------------------------------------------------------
# Scheduler invariants (no model involved).
# ---------------------------------------------------------------------------
class TestScheduler:
    def test_admission_follows_arrival_order(self):
        """Requests are admitted FIFO by arrival time, not submission order."""
        reqs = _requests([(0.3, 4), (0.1, 4), (0.2, 4)])  # rids 0,1,2
        sched = Scheduler(reqs, max_batch=2)
        sched.poll(0.15)
        assert [r.rid for _, r in sched.admit(0.15)] == [1]
        sched.poll(0.35)                       # rids 2 then 0 arrive
        assert [r.rid for _, r in sched.admit(0.35)] == [2]  # one free slot
        sched.retire(0, 0.5)                   # rid 1 finishes
        assert [r.rid for _, r in sched.admit(0.5)] == [0]

    def test_slot_reuse_after_retirement(self):
        """A retired slot is reused (lowest index first) by the next
        admission."""
        reqs = _requests([(0.0, 4)] * 5)
        sched = Scheduler(reqs, max_batch=2)
        sched.poll(0.0)
        first = sched.admit(0.0)
        assert [s for s, _ in first] == [0, 1]
        sched.retire(1, 0.1)
        nxt = sched.admit(0.1)
        assert [s for s, _ in nxt] == [1]      # freed slot reused
        sched.retire(0, 0.2)
        sched.retire(1, 0.2)
        assert [s for s, _ in sched.admit(0.2)] == [0, 1]
        assert sorted(r.rid for r in sched.finished) == [0, 1, 2]

    def test_allocator_release_guard(self):
        alloc = SlotAllocator(2)
        s = alloc.alloc(rid=7)
        alloc.release(s)
        with pytest.raises(ValueError):
            alloc.release(s)

    def test_allocator_shard_balanced(self):
        """Sharded pools (the Executor's slot-over-data layout): admission
        takes from the shard with the most free slots, lowest slot within
        the shard — successive admissions spread one per data shard."""
        alloc = SlotAllocator(8, n_shards=4)
        assert alloc.shard_of == [0, 0, 1, 1, 2, 2, 3, 3]
        first = [alloc.alloc(i) for i in range(4)]
        assert first == [0, 2, 4, 6]             # one slot per shard
        rest = [alloc.alloc(i) for i in range(4, 8)]
        assert rest == [1, 3, 5, 7]
        assert alloc.free_per_shard() == [0, 0, 0, 0]
        alloc.release(4)
        alloc.release(5)
        alloc.release(2)
        # shard 2 has the most free slots -> next admission lands there
        assert alloc.alloc(9) == 4
        assert alloc.free_per_shard() == [0, 1, 1, 0]

    def test_allocator_single_shard_is_lowest_first(self):
        """n_shards=1 (single-device no-op path) is exactly the classic
        lowest-index-first allocator."""
        alloc = SlotAllocator(3)
        assert [alloc.alloc(i) for i in range(3)] == [0, 1, 2]
        alloc.release(2)
        alloc.release(0)
        assert alloc.alloc(7) == 0

    def test_scheduler_partitions_slots_across_shards(self):
        reqs = _requests([(0.0, 4)] * 4)
        sched = Scheduler(reqs, max_batch=4, n_shards=2)
        sched.poll(0.0)
        admitted = sched.admit(0.0)
        shards = [sched.slots.shard_of[s] for s, _ in admitted]
        assert sorted(shards) == [0, 0, 1, 1]
        assert [s for s, _ in admitted] == [0, 2, 1, 3]

    def test_done_and_accounting(self):
        reqs = _requests([(0.0, 2), (0.05, 2)])
        sched = Scheduler(reqs, max_batch=1)
        sched.poll(0.1)
        (slot, r0), = sched.admit(0.1)
        assert not sched.done
        sched.retire(slot, 0.2)
        (slot, r1), = sched.admit(0.2)
        sched.retire(slot, 0.3)
        assert sched.done
        assert r0.latency_s == pytest.approx(0.2)      # arrival 0.0 -> 0.2
        assert r1.queue_s == pytest.approx(0.15)       # arrival 0.05 -> 0.2

    def test_poisson_trace_deterministic(self):
        a = poisson_trace(8, rate_rps=100, prompt_len=16, max_new=16,
                          vocab_size=99, seed=3)
        b = poisson_trace(8, rate_rps=100, prompt_len=16, max_new=16,
                          vocab_size=99, seed=3)
        assert [r.arrival_s for r in a] == [r.arrival_s for r in b]
        assert all((x.prompt == y.prompt).all() for x, y in zip(a, b))
        assert all(x.max_new <= 16 for x in a)


# ---------------------------------------------------------------------------
# Engine-level behavior on a reduced model.
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def qwen_server():
    cfg = reduced_config(get_config("qwen3-8b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    params = model.quantize(params, 8)
    cfg = dataclasses.replace(cfg, quant_mode="psi8")
    return Server(cfg, params, max_batch=2, max_seq=64)


class TestEngine:
    def test_slot_reuse_and_budgets(self, qwen_server):
        """6 requests through 2 slots: every slot is reused, every request
        gets exactly its own max_new tokens."""
        reqs = _requests([(0.0, 3), (0.0, 7), (0.0, 2), (0.0, 5),
                          (0.0, 4), (0.0, 1)])
        done, stats = qwen_server.serve(reqs, continuous=True)
        assert stats["n_requests"] == 6
        by_rid = sorted(done, key=lambda r: r.rid)
        assert [len(r.tokens) for r in by_rid] == [3, 7, 2, 5, 4, 1]
        slots = [r.slot for r in done]
        assert set(slots) <= {0, 1}
        assert min(slots.count(0), slots.count(1)) >= 2   # both reused

    def test_decode_shape_stability(self, qwen_server):
        """The jitted decode step must never recompile: varying active-slot
        masks, positions, and admissions all reuse one executable."""
        reqs = _requests([(0.0, 5), (0.002, 9), (0.004, 2), (0.006, 6)])
        qwen_server.serve(reqs, continuous=True)
        assert qwen_server.decode_cache_size() == 1
        # a second serve with a different trace still reuses it
        qwen_server.serve(_requests([(0.0, 4), (0.0, 4), (0.001, 8)]),
                          continuous=True)
        assert qwen_server.decode_cache_size() == 1

    def test_paged_kernel_no_recompile_across_table_contents(self,
                                                             qwen_server):
        """The routed paged-decode kernel path (1x1 mesh; the (4,2)-mesh
        twin lives in test_distributed.py): block tables are decode-step
        *inputs*, so steps whose tables differ only in content — new
        allocations, permuted physical blocks, freed-and-reused blocks,
        holes — must all reuse one compiled decode executable."""
        ex = qwen_server.executor
        assert ex.paged and ex.paged_attn_route is not None
        B, n_bt = ex.max_batch, ex.n_bt
        cache = ex.init_cache()
        tok = np.zeros((B, 1), np.int32)
        pos = np.ones((B, 1), np.int32)
        act = np.ones((B,), bool)
        tables = [
            np.arange(B * n_bt, dtype=np.int32).reshape(B, n_bt),    # fresh
            np.arange(B * n_bt, dtype=np.int32)[::-1].reshape(B, n_bt),
            np.full((B, n_bt), -1, np.int32),                        # freed
            np.roll(np.arange(B * n_bt, dtype=np.int32),             # reused
                    3).reshape(B, n_bt),
        ]
        tables[3][0, -1] = -1                                        # hole
        for bt in tables:
            _, cache = ex.decode(tok, pos, act, cache, block_table=bt)
        assert qwen_server.decode_cache_size() == 1

    def test_eos_retirement(self, qwen_server):
        """With an EOS id, every request's stream either stops right after
        its first EOS token or runs to its max_new budget."""
        reqs = _requests([(0.0, 12)] * 4)
        done, _ = qwen_server.serve(reqs, continuous=True)
        # pick an id that actually occurs mid-stream somewhere
        eos = None
        for r in done:
            if len(r.tokens) > 2:
                eos = r.tokens[1]
                break
        assert eos is not None
        reqs2 = _requests([(0.0, 12)] * 4)
        server = qwen_server
        old = server.eos_id
        try:
            server.eos_id = eos
            done2, _ = server.serve(reqs2, continuous=True)
        finally:
            server.eos_id = old
        for r in sorted(done2, key=lambda r: r.rid):
            if eos in r.tokens:
                assert r.tokens.index(eos) == len(r.tokens) - 1
            else:
                assert len(r.tokens) == r.max_new

    def test_instant_retirement_backlog_fully_served(self, qwen_server):
        """max_new=1 requests retire at admission time; a backlog larger
        than max_batch must still drain completely (regression: the serve
        loop used to break with the waiting queue non-empty)."""
        reqs = _requests([(0.0, 1)] * 5)
        done, stats = qwen_server.serve(reqs, continuous=True)
        assert stats["n_requests"] == 5
        assert sorted(r.rid for r in done) == [0, 1, 2, 3, 4]
        assert all(len(r.tokens) == 1 for r in done)

    def test_continuous_matches_static_outputs(self, qwen_server):
        """Greedy decode: scheduling policy may change timing, never
        tokens."""
        mk = lambda: _requests([(0.0, 6), (0.0, 3), (0.001, 8), (0.002, 5),
                                (0.003, 4)])
        done_c, _ = qwen_server.serve(mk(), continuous=True)
        done_s, _ = qwen_server.serve(mk(), continuous=False)
        for rc, rs in zip(sorted(done_c, key=lambda r: r.rid),
                          sorted(done_s, key=lambda r: r.rid)):
            assert rc.tokens == rs.tokens


# ---------------------------------------------------------------------------
# INT5 bit-plane packing round-trip (property).
# ---------------------------------------------------------------------------
class TestEngineFamilies:
    """Every family-specific serving branch: recurrent-state freezing (ssm /
    hybrid), exact-length per-request prefill, SWA ring-extent fallbacks,
    and encdec enc_out slot insertion."""

    @pytest.mark.parametrize("arch", ["falcon-mamba-7b", "recurrentgemma-9b",
                                      "mixtral-8x22b", "whisper-base"])
    def test_serve_families_schedule_invariant(self, arch):
        cfg = reduced_config(get_config(arch))
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        server = Server(cfg, params, max_batch=2, max_seq=64)

        def mk():
            rng = np.random.default_rng(1)
            # heterogeneous prompt lengths exercise the exact-length /
            # pad-fallback admission paths
            return [Request(rid=i, prompt=rng.integers(
                        0, cfg.vocab_size, size=(6 + 5 * i,)).astype(np.int32),
                        max_new=mn, arrival_s=0.0)
                    for i, mn in enumerate([5, 2, 4])]

        done_c, stats = server.serve(mk(), continuous=True)
        done_s, _ = server.serve(mk(), continuous=False)
        assert stats["n_requests"] == 3
        by_rid_c = sorted(done_c, key=lambda r: r.rid)
        assert [len(r.tokens) for r in by_rid_c] == [5, 2, 4]
        for rc, rs in zip(by_rid_c, sorted(done_s, key=lambda r: r.rid)):
            assert rc.tokens == rs.tokens
        assert server.decode_cache_size() == 1


class TestPackInt5:
    @given(st.lists(st.integers(-16, 15), min_size=8, max_size=64),
           st.integers(1, 3))
    @settings(max_examples=40, deadline=None)
    def test_roundtrip_property(self, vals, n_cols):
        """unpack(pack(x)) == x for any INT5 code matrix whose row count is a
        multiple of 8, at exactly 5 bits/weight of storage."""
        k = (len(vals) // 8) * 8
        codes = np.tile(np.asarray(vals[:k], np.int8).reshape(k, 1),
                        (1, n_cols))                        # (k, n_cols)
        packed = psi.pack_int5(jnp.asarray(codes))
        assert packed.shape == (5, k // 8, n_cols)
        out = np.asarray(psi.unpack_int5(packed))
        np.testing.assert_array_equal(out, codes)

    def test_rejects_bad_k(self):
        with pytest.raises(ValueError):
            psi.pack_int5(jnp.zeros((12, 4), jnp.int8))
