"""Paged KV-cache subsystem tests (DESIGN.md §3): BlockAllocator
properties, scheduler-level fragmentation churn, request-accounting NaN
semantics, and engine-level paged-vs-dense equivalence on the reduced
qwen3-8b config."""
import dataclasses
import random

import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config, reduced_config
from repro.launch.scheduler import (BlockAllocator, Request, Scheduler,
                                    summarize)
from repro.launch.serve import Server
from repro.models import build_model, kvcache as kvc


def _requests(specs, prompt_len=8):
    rng = np.random.default_rng(0)
    return [Request(rid=i, prompt=rng.integers(0, 256, size=(prompt_len,))
                    .astype(np.int32), max_new=mn, arrival_s=at)
            for i, (at, mn) in enumerate(specs)]


# ---------------------------------------------------------------------------
# BlockAllocator properties.
# ---------------------------------------------------------------------------
class TestBlockAllocator:
    @given(st.integers(4, 48), st.integers(1, 4), st.integers(0, 10_000))
    @settings(max_examples=40, deadline=None)
    def test_churn_invariants(self, n_blocks, n_shards, seed):
        """Random reserve/alloc/release interleavings: a block is never
        double-allocated, free + in_use == n_blocks after every op, the
        high watermark is monotone, and a full trace replay (everything
        released) restores the exact initial allocator state."""
        alloc = BlockAllocator(n_blocks, n_shards=n_shards)
        initial_free = sorted(b for pool in alloc._free for b in pool)
        rng = random.Random(seed)
        live = {}                                 # rid -> unmet reservation
        last_peak = 0
        for rid in range(rng.randint(1, 30)):
            # maybe retire someone first
            if live and rng.random() < 0.4:
                victim = rng.choice(list(live))
                alloc.release(victim)
                del live[victim]
            need = rng.randint(1, max(1, n_blocks // 2))
            if not alloc.can_reserve(need):
                continue
            alloc.reserve(rid, need)
            live[rid] = need
            for _ in range(rng.randint(0, need)):
                blk = alloc.alloc(rid,
                                  shard=rng.choice([None, 0, n_shards - 1]))
                assert 0 <= blk < n_blocks
                assert alloc.owner[blk] == rid     # never double-allocated
                live[rid] -= 1
            assert alloc.free_count + alloc.in_use == n_blocks
            assert alloc.reserved_total == sum(live.values())
            assert alloc.high_watermark >= last_peak  # monotone watermark
            last_peak = alloc.high_watermark
        for rid in list(live):
            alloc.release(rid)
        # freeing returns capacity exactly; no leaks, no duplicates
        assert alloc.free_count == n_blocks
        assert alloc.reserved_total == 0
        assert sorted(b for pool in alloc._free for b in pool) == initial_free
        assert all(o is None for o in alloc.owner)

    def test_alloc_beyond_reservation_rejected(self):
        alloc = BlockAllocator(8)
        alloc.reserve(1, 2)
        alloc.alloc(1)
        alloc.alloc(1)
        with pytest.raises(ValueError, match="beyond its reservation"):
            alloc.alloc(1)

    def test_reservation_gates_capacity(self):
        """Outstanding reservations count against can_reserve even before
        the blocks materialize — the admission guarantee that running
        requests never starve mid-decode."""
        alloc = BlockAllocator(10)
        alloc.reserve(1, 6)                       # nothing allocated yet
        assert not alloc.can_reserve(5)
        assert alloc.can_reserve(4)
        alloc.release(1)                          # early retirement returns
        assert alloc.can_reserve(10)              # the unused reservation

    def test_double_reserve_rejected(self):
        alloc = BlockAllocator(8)
        alloc.reserve(1, 2)
        with pytest.raises(ValueError, match="already holds"):
            alloc.reserve(1, 1)

    def test_shard_preference(self):
        alloc = BlockAllocator(8, n_shards=4)
        assert alloc.shard_of == [0, 0, 1, 1, 2, 2, 3, 3]
        alloc.reserve(1, 3)
        assert alloc.alloc(1, shard=2) == 4       # hint honored
        assert alloc.alloc(1, shard=2) == 5
        assert alloc.alloc(1, shard=2) in (0, 2, 6)  # exhausted: fall back


class TestSchedulerChurn:
    def test_churn_trace_restores_allocator(self):
        """Fragmentation regression: a long admit/decode-alloc/retire churn
        of heterogeneous-length requests must end with the allocator's free
        count equal to its initial free count (no leaked blocks)."""
        bs = 16
        reqs = _requests([(0.0, 1 + (7 * i) % 40) for i in range(40)])
        for i, r in enumerate(reqs):              # heterogeneous prompts
            r.prompt = r.prompt[:1 + (5 * i) % 8]
        blocks = BlockAllocator(12, n_shards=2)
        needed = lambda r: kvc.blocks_for(len(r.prompt) + r.max_new, bs)
        sched = Scheduler(reqs, max_batch=4, blocks=blocks,
                          blocks_needed=needed)
        sched.poll(0.0)
        t, rng = 0.0, random.Random(0)
        while not sched.done:
            t += 0.01
            for slot, req in sched.admit(t):
                for _ in range(kvc.blocks_for(len(req.prompt), bs)):
                    blocks.alloc(req.rid)         # prefill blocks
            # decode: occasionally cross a block boundary
            for slot, req in list(sched.running.items()):
                if rng.random() < 0.3 and blocks._reserved.get(req.rid, 0):
                    blocks.alloc(req.rid)
                if rng.random() < 0.5:
                    sched.retire(slot, t)
        assert len(sched.finished) == 40
        assert blocks.free_count == 12            # == initial free count
        assert blocks.reserved_total == 0
        assert blocks.high_watermark > 0


# ---------------------------------------------------------------------------
# Request accounting (satellite regression): unfinished -> NaN, skipped.
# ---------------------------------------------------------------------------
class TestAccounting:
    def test_unfinished_request_metrics_are_nan(self):
        r = Request(rid=0, prompt=np.zeros((4,), np.int32), max_new=4,
                    arrival_s=3.5)
        assert np.isnan(r.latency_s)              # regression: was -3.5
        assert np.isnan(r.ttft_s)
        assert np.isnan(r.queue_s)
        r.admit_s = 4.0
        assert r.queue_s == pytest.approx(0.5)
        assert np.isnan(r.latency_s)
        r.first_token_s, r.finish_s = 4.25, 5.5
        assert r.ttft_s == pytest.approx(0.75)
        assert r.latency_s == pytest.approx(2.0)

    def test_summarize_skips_unfinished(self):
        reqs = _requests([(0.0, 4), (0.0, 4), (0.0, 4)])
        for r in reqs[:2]:
            r.admit_s, r.first_token_s, r.finish_s = 0.1, 0.2, 1.0
            r.tokens = [1, 2]
        reqs[2].tokens = [3]                      # arrived, never finished
        stats = summarize(reqs, wall_s=2.0)
        assert stats["p99_latency_s"] == pytest.approx(1.0)
        assert stats["p50_ttft_s"] == pytest.approx(0.2)
        assert stats["tokens"] == 5
        stats_none = summarize([reqs[2]], wall_s=1.0)
        assert stats_none["p99_latency_s"] == 0.0  # all-NaN degrades to 0


# ---------------------------------------------------------------------------
# Engine-level paged serving (reduced qwen3-8b).
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def qwen_setup():
    cfg = reduced_config(get_config("qwen3-8b"))
    model = build_model(cfg)
    params = model.quantize(model.init(jax.random.PRNGKey(0)), 8)
    cfg = dataclasses.replace(cfg, quant_mode="psi8")
    return cfg, params


class TestPagedEngine:
    def test_paged_is_default_and_token_identical_to_dense(self, qwen_setup):
        """Acceptance: paged serving (the full-attention default) emits
        exactly the dense layout's greedy tokens in both scheduling modes,
        with the decode step compiling once per server."""
        cfg, params = qwen_setup
        assert cfg.resolved_cache_layout == "paged"

        def mk():
            return _requests([(0.0, 3), (0.0, 7), (0.001, 2), (0.002, 5),
                              (0.003, 4), (0.004, 6)])

        dense = Server(dataclasses.replace(cfg, cache_layout="dense"),
                       params, max_batch=2, max_seq=64)
        paged = Server(cfg, params, max_batch=2, max_seq=64)
        assert paged.paged and not dense.paged
        done_d, stat_d = dense.serve(mk(), continuous=True)
        done_pc, stat_pc = paged.serve(mk(), continuous=True)
        done_ps, stat_ps = paged.serve(mk(), continuous=False)
        toks = lambda done: {r.rid: tuple(r.tokens) for r in done}
        assert toks(done_d) == toks(done_pc) == toks(done_ps)
        assert stat_pc["decode_compiles"] == 1
        assert stat_d["decode_compiles"] == 1
        assert stat_pc["cache_layout"] == "paged"
        assert stat_pc["blocks_free_end"] == stat_pc["n_blocks"]

    def test_paged_kv_int8_matches_dense(self):
        """The paged int8-KV path (per-entry scale pools scattered at
        insert, gathered+dequantized at decode) is token-identical to the
        dense int8 ring — the k/v_scale branch of
        paged_decode_attention_block has no other coverage."""
        cfg = reduced_config(get_config("qwen3-8b"), kv_quant="int8")
        model = build_model(cfg)
        params = model.quantize(model.init(jax.random.PRNGKey(0)), 8)
        cfg = dataclasses.replace(cfg, quant_mode="psi8")

        def mk():
            rng = np.random.default_rng(1)
            return [Request(rid=i, prompt=rng.integers(
                        0, cfg.vocab_size, size=(6 + 3 * i,))
                        .astype(np.int32), max_new=mn, arrival_s=0.0)
                    for i, mn in enumerate([4, 6, 3])]

        dense = Server(dataclasses.replace(cfg, cache_layout="dense"),
                       params, max_batch=2, max_seq=48)
        paged = Server(cfg, params, max_batch=2, max_seq=48)
        done_d, _ = dense.serve(mk(), continuous=True)
        done_p, stat_p = paged.serve(mk(), continuous=True)
        assert {r.rid: r.tokens for r in done_d} == \
               {r.rid: r.tokens for r in done_p}
        assert stat_p["decode_compiles"] == 1
        assert stat_p["blocks_free_end"] == stat_p["n_blocks"]

    def test_engine_churn_returns_all_blocks(self, qwen_setup):
        """Engine-level fragmentation regression: many heterogeneous
        admit/retire cycles through a small paged pool end with every
        block back in the free list."""
        cfg, params = qwen_setup
        rng = np.random.default_rng(2)
        reqs = [Request(rid=i,
                        prompt=rng.integers(0, cfg.vocab_size,
                                            size=(3 + (11 * i) % 20,))
                        .astype(np.int32),
                        max_new=1 + (5 * i) % 12, arrival_s=0.002 * i)
                for i in range(12)]
        server = Server(cfg, params, max_batch=2, max_seq=48, n_blocks=6)
        done, stats = server.serve(reqs, continuous=True)
        assert stats["n_requests"] == 12
        assert stats["blocks_free_end"] == 6 == stats["n_blocks"]
        assert 0 < stats["peak_blocks_in_use"] <= 6
        assert stats["block_util_pct"] <= 100.0

    def test_block_gated_admission_still_serves_all(self, qwen_setup):
        """A pool smaller than the slot count's worst case gates admission
        (head-of-line waits for blocks, no deadlock) and every request
        still completes with its full budget."""
        cfg, params = qwen_setup
        reqs = _requests([(0.0, 8)] * 5, prompt_len=6)
        # each request worst-case: ceil(max(16, 6+8)/16) = 1 block; pool of
        # 2 blocks but 4 slots: at most 2 concurrent despite 4 free slots
        server = Server(cfg, params, max_batch=4, max_seq=32, n_blocks=2)
        done, stats = server.serve(reqs, continuous=True)
        assert stats["n_requests"] == 5
        assert all(len(r.tokens) == 8 for r in done)
        assert stats["peak_concurrency"] <= 2
        assert stats["blocks_free_end"] == 2

    def test_oversized_request_fails_fast(self, qwen_setup):
        cfg, params = qwen_setup
        server = Server(cfg, params, max_batch=2, max_seq=64, n_blocks=1)
        reqs = _requests([(0.0, 40)], prompt_len=8)   # needs 3 blocks
        with pytest.raises(ValueError, match="more blocks than the pool"):
            server.serve(reqs)

    def test_warmup_skips_unreachable_burst_shapes(self, qwen_setup):
        """Satellite: a 1-request trace can never co-admit, so warmup must
        not compile the max_batch burst prefill path (and must log/return
        the compile count)."""
        cfg, params = qwen_setup
        server = Server(cfg, params, max_batch=4, max_seq=48)
        single = _requests([(0.0, 4)])
        n = server.warmup(single, verbose=False)
        sizes = server.executor.prefill_cache_sizes()
        assert sizes["prefill"] in (0, -1)        # burst path not compiled
        assert sizes["insert_burst"] in (0, -1)
        assert sizes["prefill_insert"] >= 1 or sizes["prefill_insert"] == -1
        assert n == 2                             # fused prefill + decode
        done, _ = server.serve(_requests([(0.0, 4)]), warmup=False)
        assert len(done[0].tokens) == 4
        # a multi-request trace does need (and compile) the burst path
        n_multi = server.warmup(_requests([(0.0, 4)] * 3), verbose=False)
        assert n_multi == 4
        assert server.executor.prefill_cache_sizes()["prefill"] in (1, -1)

    @pytest.mark.parametrize("layout,expected", [("paged", 7), ("dense", 6)])
    def test_warmup_compile_count_multi_bucket(self, qwen_setup, layout,
                                               expected):
        """Two prompt buckets: the burst INSERT compiles per bucket only
        for paged (the seq-cache extent follows the bucket); dense prefills
        at max_seq, so one insert executable covers both buckets — the
        logged count must match what actually compiled."""
        cfg, params = qwen_setup
        server = Server(dataclasses.replace(cfg, cache_layout=layout),
                        params, max_batch=2, max_seq=64)
        reqs = _requests([(0.0, 4)] * 2, prompt_len=8) + \
            _requests([(0.0, 4)] * 2, prompt_len=20)
        n = server.warmup(reqs, verbose=False)
        assert n == expected        # 2 fused + 2 prefill + insert(s) + decode
        sizes = server.executor.prefill_cache_sizes()
        if sizes["insert_burst"] != -1:
            assert sizes["insert_burst"] == (2 if layout == "paged" else 1)


class TestKVCacheType:
    def test_pytree_roundtrip_preserves_layout(self):
        cache = kvc.KVCache(kv={"x": np.zeros((2, 2))}, layout=kvc.PAGED,
                            block_size=16, n_blocks=8)
        leaves, treedef = jax.tree_util.tree_flatten(cache)
        back = jax.tree_util.tree_unflatten(treedef, leaves)
        assert back.layout == kvc.PAGED
        assert back.block_size == 16 and back.n_blocks == 8 and back.paged

    def test_layout_resolution_guards(self):
        swa = reduced_config(get_config("mixtral-8x22b"))
        assert swa.resolved_cache_layout == "dense"      # SWA -> dense
        with pytest.raises(ValueError, match="paged"):
            dataclasses.replace(swa, cache_layout="paged") \
                .resolved_cache_layout
        ssm = reduced_config(get_config("falcon-mamba-7b"))
        assert ssm.resolved_cache_layout == "dense"
        dense_forced = reduced_config(get_config("qwen3-8b"),
                                      cache_layout="dense")
        assert dense_forced.resolved_cache_layout == "dense"

    def test_helpers(self):
        assert kvc.blocks_for(1, 16) == 1
        assert kvc.blocks_for(16, 16) == 1
        assert kvc.blocks_for(17, 16) == 2
        assert kvc.table_width(96, 16) == 6
        sds = jax.ShapeDtypeStruct((4, 2), np.float32)
        assert kvc.cache_nbytes({"a": sds}) == 32
