"""Pallas psi_matmul kernels vs the pure-jnp oracle: shape/dtype sweeps in
interpret mode (the kernel body executes on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import psi
from repro.kernels import ops, psi_matmul as pk, ref


def _quant(w, bits):
    q = psi.quantize_weights(w, bits, axis=0)
    return q.codes, q.scale.reshape(-1)


SHAPES = [
    (8, 16, 8),          # tiny (full padding path)
    (128, 128, 128),     # exactly one tile
    (200, 136, 72),      # ragged, all dims padded
    (256, 384, 256),     # multi-tile
    (1, 512, 128),       # decode-like M=1
]


@pytest.mark.parametrize("M,K,N", SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_int8_kernel_vs_ref(M, K, N, dtype):
    rng = np.random.default_rng(hash((M, K, N)) % 2 ** 31)
    x = jnp.asarray(rng.normal(size=(M, K)), dtype)
    w = jnp.asarray(rng.normal(size=(K, N)).astype(np.float32))
    codes, scale = _quant(w, 8)
    got = pk.psi_matmul_int8(x, codes, scale, interpret=True)
    want = ref.psi_matmul_int8_ref(x, codes, scale)
    # bf16 outputs may differ by 1 ulp (tiled vs single-einsum f32
    # accumulation order rounds differently at the bf16 cast)
    tol = dict(rtol=1e-5, atol=1e-4) if dtype == jnp.float32 \
        else dict(rtol=2e-2, atol=2e-2)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **tol)


@pytest.mark.parametrize("M,K,N", SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_int5_kernel_vs_ref(M, K, N, dtype):
    rng = np.random.default_rng(hash((M, K, N, 5)) % 2 ** 31)
    x = jnp.asarray(rng.normal(size=(M, K)), dtype)
    w = jnp.asarray(rng.normal(size=(K, N)).astype(np.float32))
    q = psi.quantize_weights(w, 5, axis=0)
    planes = psi.pack_int5(q.codes)
    scale = q.scale.reshape(-1)
    got = pk.psi_matmul_int5(x, planes, scale, interpret=True)
    want = ref.psi_matmul_int5_ref(x, planes, scale)
    tol = dict(rtol=1e-5, atol=1e-4) if dtype == jnp.float32 \
        else dict(rtol=2e-2, atol=2e-2)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **tol)


def test_int8_kernel_block_shape_sweep():
    """Kernel result is block-shape invariant (accumulation correctness)."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(96, 160)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(160, 192)).astype(np.float32))
    codes, scale = _quant(w, 8)
    want = ref.psi_matmul_int8_ref(x, codes, scale)
    for bm, bn, bk in [(32, 64, 32), (128, 128, 128), (96, 192, 160),
                       (16, 16, 16)]:
        got = pk.psi_matmul_int8(x, codes, scale, bm=bm, bn=bn, bk=bk,
                                 interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-4)


def test_int5_kernel_block_shape_sweep():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(64, 128)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(128, 96)).astype(np.float32))
    q = psi.quantize_weights(w, 5, axis=0)
    planes = psi.pack_int5(q.codes)
    scale = q.scale.reshape(-1)
    want = ref.psi_matmul_int5_ref(x, planes, scale)
    for bm, bn, bk in [(32, 32, 32), (64, 96, 64), (64, 96, 128)]:
        got = pk.psi_matmul_int5(x, planes, scale, bm=bm, bn=bn, bk=bk,
                                 interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-4)


def test_ops_dispatch_cpu_matches_interpret(monkeypatch):
    """ops.psi_matmul (CPU oracle path) == forced interpret-kernel path."""
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(4, 10, 64)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(64, 48)).astype(np.float32))
    leaf = psi.quantize_weights(w, 8, axis=0)
    got_ref = ops.psi_matmul(x, leaf)
    monkeypatch.setenv("REPRO_FORCE_INTERPRET", "1")
    got_kernel = ops.psi_matmul(x, leaf)
    np.testing.assert_allclose(np.asarray(got_ref), np.asarray(got_kernel),
                               rtol=1e-5, atol=1e-4)
    assert got_ref.shape == (4, 10, 48)


class TestDecodeDispatch:
    """Small-M tile dispatch: the decode step (M = active slots <= 16) must
    not pad M up to the 128-row MXU tile."""

    def test_pick_bm_tile_floor_and_cap(self):
        assert pk.pick_bm(1, jnp.float32) == 8
        assert pk.pick_bm(8, jnp.float32) == 8
        assert pk.pick_bm(16, jnp.float32) == 16
        assert pk.pick_bm(1, jnp.bfloat16) == 16     # bf16 sublane floor
        assert pk.pick_bm(16, jnp.bfloat16) == 16
        assert pk.pick_bm(128, jnp.float32) == 128
        assert pk.pick_bm(4096, jnp.bfloat16) == 128

    def test_padded_macs_ratio_at_decode_shapes(self):
        for M in (1, 4, 8, 16):
            old = pk.padded_macs(M, 2048, 2048)
            new = pk.padded_macs(M, 2048, 2048,
                                 bm=pk.pick_bm(M, jnp.float32))
            assert old / new >= 2.0, (M, old, new)

    @pytest.mark.parametrize("M", [1, 4, 16])
    def test_small_m_tiles_match_ref(self, M):
        rng = np.random.default_rng(M)
        x = jnp.asarray(rng.normal(size=(M, 256)).astype(np.float32))
        w = jnp.asarray(rng.normal(size=(256, 192)).astype(np.float32))
        codes, scale = _quant(w, 8)
        bm = pk.pick_bm(M, x.dtype)
        got = pk.psi_matmul_int8(x, codes, scale, bm=bm, interpret=True)
        want = ref.psi_matmul_int8_ref(x, codes, scale)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-4)


class TestGpuFastPath:
    """The dequantize-then-einsum route for non-TPU accelerators must agree
    with the oracle (scale folded into W commutes with the contraction)."""

    def test_int8_dequant_matches_oracle(self):
        rng = np.random.default_rng(7)
        x = jnp.asarray(rng.normal(size=(5, 128)).astype(np.float32))
        w = jnp.asarray(rng.normal(size=(128, 96)).astype(np.float32))
        codes, scale = _quant(w, 8)
        got = ref.psi_matmul_int8_dequant(x, codes, scale)
        want = ref.psi_matmul_int8_ref(x, codes, scale)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)

    def test_int5_dequant_matches_oracle(self):
        rng = np.random.default_rng(8)
        x = jnp.asarray(rng.normal(size=(3, 64)).astype(np.float32))
        w = jnp.asarray(rng.normal(size=(64, 40)).astype(np.float32))
        q = psi.quantize_weights(w, 5, axis=0)
        planes = psi.pack_int5(q.codes)
        scale = q.scale.reshape(-1)
        got = ref.psi_matmul_int5_dequant(x, planes, scale)
        want = ref.psi_matmul_int5_ref(x, planes, scale)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)

    def test_backend_routing_is_explicit(self, monkeypatch):
        """A gpu backend must route to the dequant fast path, never the
        bit-plane oracle loop or (worse) a silent CPU fall-through."""
        calls = []
        monkeypatch.setattr(ops, "_backend", lambda: "gpu")
        monkeypatch.setattr(
            ops._ref, "psi_matmul_packed_dequant",
            lambda x, p, s, b: calls.append(f"dequant_packed{b}")
            or ref.psi_matmul_packed_ref(x, p, s, b))
        monkeypatch.setattr(
            ops._ref, "psi_matmul_codes_dequant",
            lambda *a: calls.append("dequant_codes")
            or ref.psi_matmul_codes_ref(*a))
        rng = np.random.default_rng(9)
        x = jnp.asarray(rng.normal(size=(2, 64)).astype(np.float32))
        w = jnp.asarray(rng.normal(size=(64, 40)).astype(np.float32))
        ops.psi_matmul(x, psi.quantize_weights(w, 5, axis=0).pack())
        ops.psi_matmul(x, psi.quantize_weights(w, 8, axis=0))
        assert calls == ["dequant_packed5", "dequant_codes"]


def test_packed_kernel_every_sub_byte_width():
    """One kernel body serves every registered sub-byte format: the
    interpret-mode Pallas packed kernel matches the oracle for each."""
    rng = np.random.default_rng(12)
    x = jnp.asarray(rng.normal(size=(4, 64)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(64, 24)).astype(np.float32))
    for bits in psi.registered_bits():
        if not psi.get_format(bits).sub_byte:
            continue
        q = psi.quantize_weights(w, bits, axis=0).pack()
        scale = q.scale.reshape(-1)
        got = pk.psi_matmul_packed(x, q.data, scale, bits=bits,
                                   interpret=True)
        want = ref.psi_matmul_packed_ref(x, q.data, scale, bits)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)


def test_kernel_matches_float_matmul_within_quant_error():
    """End-to-end sanity: the PSI kernel approximates the float matmul with
    per-channel-quantization error bounds (not exactness)."""
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(32, 256)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(256, 64)).astype(np.float32))
    codes, scale = _quant(w, 8)
    got = pk.psi_matmul_int8(x, codes, scale, interpret=True)
    rel = float(jnp.linalg.norm(got - x @ w) / jnp.linalg.norm(x @ w))
    assert rel < 0.02
