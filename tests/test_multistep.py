"""Multi-step decode tests (DESIGN.md §3 "Multi-step decode & host
overlap"): token identity of horizon-M rounds vs the step-at-a-time engine
across cache layouts and KV quant modes, EOS retirement landing at every
in-round offset, max_new not a multiple of M, preemption firing between
rounds, the one-compile warmup contract, the DeviceBlockTable zero-transfer
regression, and the idle-loop iteration bound (no 5 ms busy-spin)."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced_config
from repro.launch.scheduler import Request, replay_round
from repro.launch.serve import Server
from repro.launch.slo import bursty_heavy_tail_trace, parse_slo_spec
from repro.models import build_model


@pytest.fixture(scope="module")
def qwen_setup():
    cfg = reduced_config(get_config("qwen3-8b"))
    model = build_model(cfg)
    params = model.quantize(model.init(jax.random.PRNGKey(0)), 8)
    cfg = dataclasses.replace(cfg, quant_mode="psi8")
    return cfg, params


def _requests(cfg, specs, prompt_len=8, seed=0):
    """specs: list of (arrival_s, max_new)."""
    rng = np.random.default_rng(seed)
    return [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size,
                                               size=(prompt_len,))
                    .astype(np.int32), max_new=mn, arrival_s=at)
            for i, (at, mn) in enumerate(specs)]


def _toks(done):
    return {r.rid: tuple(r.tokens) for r in done}


# ---------------------------------------------------------------------------
# Host-side round replay: the exact device retirement recurrence.
# ---------------------------------------------------------------------------
class TestReplayRound:
    def test_eos_and_budget_retirement(self):
        toks = np.array([[5, 9], [7, 9], [5, 9], [6, 9]], np.int32)
        emitted, act, rem = replay_round(
            toks, np.array([True, True]), np.array([8, 2], np.int32),
            eos_id=7)
        # slot 0 hits EOS at step 1 (the EOS token IS emitted, matching
        # the horizon-1 loop); slot 1 runs out of budget after 2 tokens.
        assert emitted[0] == [5, 7] and emitted[1] == [9, 9]
        assert not act[0] and not act[1]
        assert rem[0] == 6 and rem[1] == 0

    def test_inactive_rows_emit_nothing(self):
        toks = np.array([[1, 2]], np.int32)
        emitted, act, rem = replay_round(
            toks, np.array([False, True]), np.array([4, 4], np.int32),
            eos_id=-1)
        assert emitted[0] == [] and emitted[1] == [2]
        assert act[1] and rem[1] == 3 and rem[0] == 4


# ---------------------------------------------------------------------------
# Construction-time validation.
# ---------------------------------------------------------------------------
class TestValidation:
    def test_horizon_must_be_positive(self, qwen_setup):
        cfg, params = qwen_setup
        with pytest.raises(ValueError, match=">= 1"):
            Server(cfg, params, max_batch=2, max_seq=64, decode_horizon=-2)

    def test_horizon_rejects_speculative(self, qwen_setup):
        cfg, params = qwen_setup
        with pytest.raises(ValueError, match="speculative"):
            Server(cfg, params, max_batch=2, max_seq=64,
                   decode_horizon=4, speculative=(4, 4))


# ---------------------------------------------------------------------------
# Token-identity fuzz: horizon x layout x kv_quant.
# ---------------------------------------------------------------------------
# (layout, kv_quant): int8 KV applies to the paged pool only.
_COMBOS = [("dense", "none"), ("paged", "none"), ("paged", "int8")]


class TestHorizonIdentity:
    @pytest.mark.parametrize("layout,kvq", _COMBOS)
    def test_identity_across_horizons(self, qwen_setup, layout, kvq):
        """Horizons {2, 4, 8} emit bit-identical streams to horizon 1 for
        the same trace — staggered arrivals, mixed max_new (none a multiple
        of any horizon), mid-serve slot reuse."""
        cfg, params = qwen_setup
        cfg = dataclasses.replace(cfg, cache_layout=layout, kv_quant=kvq)
        specs = [(0.0, 3), (0.0, 7), (0.01, 2), (0.01, 5), (0.02, 9),
                 (0.02, 6)]
        base = Server(cfg, params, max_batch=3, max_seq=64)
        d0, s0 = base.serve(_requests(cfg, specs), continuous=True)
        assert s0["decode_horizon"] == 1
        for m in (2, 4, 8):
            srv = Server(cfg, params, max_batch=3, max_seq=64,
                         decode_horizon=m)
            d1, s1 = srv.serve(_requests(cfg, specs), continuous=True)
            assert _toks(d1) == _toks(d0), (layout, kvq, m)
            assert s1["decode_horizon"] == m
            assert s1["decode_rounds"] > 0
            assert s1["decode_compiles"] == 1, (m, s1["decode_compiles"])
            # exact lengths survive the in-round budget mask
            lens = {r.rid: len(r.tokens) for r in d1}
            assert lens == {i: mn for i, (_, mn) in enumerate(specs)}
            if layout == "paged":
                assert s1["blocks_free_end"] == s1["n_blocks"]

    def test_max_new_not_multiple_of_horizon(self, qwen_setup):
        """max_new in {1, 3, 5, 7, 9} at M=4: the remaining-budget mask
        retires each slot mid-round at the exact length."""
        cfg, params = qwen_setup
        specs = [(0.0, mn) for mn in (1, 3, 5, 7, 9)]
        base = Server(cfg, params, max_batch=4, max_seq=64)
        d0, _ = base.serve(_requests(cfg, specs, seed=3), continuous=True)
        srv = Server(cfg, params, max_batch=4, max_seq=64, decode_horizon=4)
        d1, s1 = srv.serve(_requests(cfg, specs, seed=3), continuous=True)
        assert _toks(d1) == _toks(d0)
        assert {r.rid: len(r.tokens) for r in d1} == \
            {i: mn for i, (_, mn) in enumerate(specs)}
        assert s1["decode_compiles"] == 1

    def test_eos_mid_round_at_every_offset(self, qwen_setup):
        """M=4: pick an EOS id that lands at each in-round offset
        {0, 1, 2, 3} of a single request's stream; horizon-4 retires the
        slot inside the scan and still matches horizon 1 exactly (the EOS
        token itself is emitted, then the row masks off)."""
        cfg, params = qwen_setup
        ref = Server(cfg, params, max_batch=1, max_seq=64)
        d_ref, _ = ref.serve(_requests(cfg, [(0.0, 12)], seed=5),
                             continuous=True)
        stream = list(d_ref[0].tokens)
        # decode emission i is stream[1 + i] (stream[0] comes from
        # prefill); its in-round offset at M=4 is i % 4.
        hit = 0
        for off in range(4):
            idx = next((1 + i for i in range(len(stream) - 1)
                        if i % 4 == off
                        and stream[1 + i] not in stream[:1 + i]), None)
            if idx is None:
                continue                      # eos would truncate earlier
            hit += 1
            eos = int(stream[idx])
            h1 = Server(cfg, params, max_batch=1, max_seq=64, eos_id=eos)
            h4 = Server(cfg, params, max_batch=1, max_seq=64, eos_id=eos,
                        decode_horizon=4)
            t1 = _toks(h1.serve(_requests(cfg, [(0.0, 12)], seed=5),
                                continuous=True)[0])
            t4 = _toks(h4.serve(_requests(cfg, [(0.0, 12)], seed=5),
                                continuous=True)[0])
            assert t1 == t4, off
            assert t4[0][-1] == eos and len(t4[0]) == idx + 1, off
        assert hit >= 3                       # >=3 distinct offsets hit

    def test_preemption_between_rounds(self, qwen_setup):
        """SLO + chunked prefill + horizon 4 on the deliberately tight
        block pool: preemption fires between rounds (the in-flight round
        drains first), streams stay identical to the FIFO horizon-1
        baseline, and no block leaks."""
        cfg, params = qwen_setup
        pol = parse_slo_spec("default@aging=5@reserve=0.1")
        trace = lambda: bursty_heavy_tail_trace(
            16, vocab_size=cfg.vocab_size, seed=7, burst_size=8,
            burst_gap_s=0.3, long_frac=0.6, mix=pol.mix([3.0, 2.0, 1.0]))
        fifo = Server(cfg, params, max_batch=4, max_seq=112, n_blocks=8)
        multi = Server(cfg, params, max_batch=4, max_seq=112, n_blocks=8,
                       prefill_chunk=16, slo=pol, decode_horizon=4)
        d0, s0 = fifo.serve(trace(), continuous=True)
        d1, s1 = multi.serve(trace(), continuous=True)
        assert _toks(d0) == _toks(d1)
        assert s1["preemptions"] > 0
        assert s1["decode_compiles"] == 1
        assert s1["blocks_free_end"] == s1["n_blocks"]
        assert s0["blocks_free_end"] == s0["n_blocks"]

    def test_compile_contract_and_sync_drop(self, qwen_setup):
        """Warmup pre-compiles exactly ONE decode_multi executable (and no
        horizon-1 step); serving syncs the host once per round, not once
        per token."""
        cfg, params = qwen_setup
        specs = [(0.0, 17)] * 4
        srv = Server(cfg, params, max_batch=4, max_seq=64, decode_horizon=8)
        _, s = srv.serve(_requests(cfg, specs), continuous=True)
        assert srv.executor.multi_cache_sizes() == \
            {"decode_multi": 1, "decode": 0}
        assert s["decode_compiles"] == 1
        assert s["host_syncs"] > 0
        # 4 x 17 = 68 tokens; per-token syncing would be >= 64 decode
        # syncs alone.
        assert s["host_syncs_per_token"] <= 0.25, s
        # 16 decode emissions per slot, 4 slots in lockstep -> 2 useful
        # rounds, plus at most one pipelined trailing all-masked round.
        assert 2 <= s["decode_rounds"] <= 3, s["decode_rounds"]


# ---------------------------------------------------------------------------
# Satellite: DeviceBlockTable transfer caching.
# ---------------------------------------------------------------------------
class TestDeviceBlockTable:
    def test_zero_transfer_when_unchanged(self, qwen_setup):
        """An unchanged table returns the SAME committed device array —
        no host->device transfer — and one dirty row of four goes up as a
        single-row scatter, not a full upload."""
        cfg, params = qwen_setup
        srv = Server(cfg, params, max_batch=4, max_seq=64)
        ex = srv.executor
        bt = ex.make_block_table()
        bt[0, :] = 0
        d0 = bt.device()
        assert bt.stats["full_uploads"] == 1
        d1 = bt.device()
        assert d1 is d0                        # cached object, zero bytes
        assert bt.stats["reuses"] == 1
        v = bt.version
        bt[1, 0] = 3                           # 1 dirty row of 4 -> scatter
        assert bt.version == v + 1
        d2 = bt.device()
        assert d2 is not d1
        assert bt.stats["row_updates"] == 1
        assert bt.stats["full_uploads"] == 1   # unchanged
        np.testing.assert_array_equal(np.asarray(d2), bt.host)
        bt[0] = -1                             # 3 dirty rows of 4 -> full
        bt[2, :] = 1
        bt[3, :] = 2
        bt.device()
        assert bt.stats["full_uploads"] == 2
        assert bt.device() is bt.device()      # steady state reuses again

    def test_serve_reuses_table_across_rounds(self, qwen_setup):
        """A long single-slot decode re-dispatches the same device table:
        stats['block_table_transfers'] shows reuses dominating uploads."""
        cfg, params = qwen_setup
        srv = Server(cfg, params, max_batch=2, max_seq=96, decode_horizon=2)
        _, s = srv.serve(_requests(cfg, [(0.0, 24)]), continuous=True)
        tr = s["block_table_transfers"]
        assert tr["reuses"] > 0
        assert tr["reuses"] > tr["full_uploads"] + tr["row_updates"] - 2


# ---------------------------------------------------------------------------
# Satellite: idle path sleeps the actual wait (no 5 ms busy-spin).
# ---------------------------------------------------------------------------
class TestIdleLoop:
    def test_sparse_trace_loop_iters_bounded(self, qwen_setup):
        """Four requests spread 0.3 s apart: the loop sleeps each gap in
        O(1) iterations instead of spinning 5 ms slices (~60 iterations
        per gap under the old path)."""
        cfg, params = qwen_setup
        specs = [(0.0, 5), (0.3, 5), (0.6, 5), (0.9, 5)]
        srv = Server(cfg, params, max_batch=2, max_seq=64)
        done, s = srv.serve(_requests(cfg, specs), continuous=True)
        assert len(done) == 4
        steps = sum(mn for _, mn in specs)     # decode iterations
        assert s["loop_iters"] <= steps + 8 * len(specs), s["loop_iters"]
