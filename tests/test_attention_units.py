"""Unit + property tests for attention internals, MoE invariants, and the
quantized-embedding paths."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config, reduced_config
from repro.models import attention, moe
from repro.models.layers import apply_rope


def _cfg(**kw):
    return reduced_config(get_config("qwen3-8b"), **kw)


class TestSDPA:
    def test_chunked_matches_dense(self):
        """Chunked prefill == unchunked attention (incl. padded tail)."""
        cfg = _cfg()
        B, S, H, D = 2, 48, 4, 16
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(k1, (B, S, H, D))
        k = jax.random.normal(k2, (B, S, 2, D))
        v = jax.random.normal(k3, (B, S, 2, D))
        pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        dense = attention.sdpa(q, k, v, pos, pos, causal=True, q_chunk=S + 1)
        chunked = attention.sdpa(q, k, v, pos, pos, causal=True, q_chunk=16)
        np.testing.assert_allclose(np.asarray(dense), np.asarray(chunked),
                                   rtol=1e-5, atol=1e-5)

    def test_chunk_padding_path(self):
        """Sq not divisible by chunk (whisper's 1500-frame encoder)."""
        B, S = 1, 37
        q = jax.random.normal(jax.random.PRNGKey(0), (B, S, 2, 8))
        pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        dense = attention.sdpa(q, q, q, pos, pos, causal=False, q_chunk=S + 1)
        chunked = attention.sdpa(q, q, q, pos, pos, causal=False, q_chunk=16)
        np.testing.assert_allclose(np.asarray(dense), np.asarray(chunked),
                                   rtol=1e-5, atol=1e-5)

    def test_causality(self):
        """Future tokens cannot influence past outputs."""
        B, S, H, D = 1, 16, 2, 8
        k1, _ = jax.random.split(jax.random.PRNGKey(1))
        q = jax.random.normal(k1, (B, S, H, D))
        pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        o1 = attention.sdpa(q, q, q, pos, pos, causal=True)
        q2 = q.at[:, -1].set(99.0)
        o2 = attention.sdpa(q2, q2, q2, pos, pos, causal=True)
        np.testing.assert_allclose(np.asarray(o1[:, :-1]),
                                   np.asarray(o2[:, :-1]), rtol=1e-5)

    @given(st.integers(4, 24))
    @settings(max_examples=8, deadline=None)
    def test_window_mask_property(self, window):
        """With window w, output at position i only depends on positions
        in (i-w, i]."""
        B, S, H, D = 1, 32, 1, 4
        q = jax.random.normal(jax.random.PRNGKey(2), (B, S, H, D))
        pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        o1 = attention.sdpa(q, q, q, pos, pos, causal=True, window=window)
        i = S - 1
        cutoff = i - window  # positions <= cutoff are invisible to i
        if cutoff >= 0:
            q2 = q.at[:, cutoff].set(37.0)
            o2 = attention.sdpa(q2, q2, q2, pos, pos, causal=True,
                                window=window)
            np.testing.assert_allclose(np.asarray(o1[:, i]),
                                       np.asarray(o2[:, i]), rtol=1e-4,
                                       atol=1e-4)

    def test_ring_buffer_decode_wraps(self):
        """SWA ring cache: decoding past the window keeps exactly the last
        `window` keys visible."""
        cfg = reduced_config(get_config("mixtral-8x22b"))
        p = attention.init_attention(cfg, jax.random.PRNGKey(0))
        B, W = 1, cfg.window
        cache = attention.init_kv_cache(cfg, B, W * 3, dtype=jnp.float32)
        assert cache["k"].shape[1] == W    # bounded by window
        x = jax.random.normal(jax.random.PRNGKey(1), (B, 1, cfg.d_model))
        for pos in range(W + 4):           # wrap the ring
            y, cache = attention.decode_attention_block(
                p, x, cfg, jnp.asarray([[pos]]), cache)
        kp = np.asarray(cache["k_pos"][0])
        assert sorted(kp) == list(range(4, W + 4))


class TestRoPE:
    @pytest.mark.parametrize("mode", ["rope", "rope2d"])
    def test_rotation_preserves_norm(self, mode):
        cfg = _cfg(rope=mode)
        x = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 4, 16))
        pos = jnp.broadcast_to(jnp.arange(8)[None], (2, 8))
        y = apply_rope(x, pos, cfg)
        np.testing.assert_allclose(np.linalg.norm(np.asarray(y), axis=-1),
                                   np.linalg.norm(np.asarray(x), axis=-1),
                                   rtol=1e-5)

    def test_relative_property(self):
        """<rope(q,i), rope(k,j)> depends only on i-j."""
        cfg = _cfg(rope="rope")
        q = jax.random.normal(jax.random.PRNGKey(0), (1, 1, 1, 16))
        k = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, 16))
        def dot_at(i, j):
            qi = apply_rope(q, jnp.asarray([[i]]), cfg)
            kj = apply_rope(k, jnp.asarray([[j]]), cfg)
            return float(jnp.sum(qi * kj))
        assert dot_at(3, 1) == pytest.approx(dot_at(10, 8), rel=1e-4)

    def test_mrope_sections_independent(self):
        """Changing the h-position stream must not affect the t-section."""
        cfg = reduced_config(get_config("qwen2-vl-2b"))
        x = jax.random.normal(jax.random.PRNGKey(0), (1, 4, 2, 16))
        p1 = jnp.stack([jnp.arange(4)[None]] * 3, axis=1)       # (1,3,4)
        p2 = p1.at[:, 1].add(7)                                  # shift h only
        y1 = apply_rope(x, p1, cfg)
        y2 = apply_rope(x, p2, cfg)
        nf = 8  # D/2
        s_t = nf // 2
        # t-section (first s_t freq pairs) unchanged
        np.testing.assert_allclose(np.asarray(y1[..., :s_t]),
                                   np.asarray(y2[..., :s_t]), rtol=1e-6)
        assert not np.allclose(np.asarray(y1), np.asarray(y2))


class TestMoE:
    def _setup(self, cf=8.0):
        cfg = reduced_config(get_config("qwen3-moe-30b-a3b"),
                             capacity_factor=cf)
        p = moe.init_moe(cfg, jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
        return cfg, p, x

    def test_output_shape_and_aux(self):
        cfg, p, x = self._setup()
        y, aux = moe.moe_ffn(p, x, cfg)
        assert y.shape == x.shape
        assert float(aux) > 0

    def test_capacity_dropping_degrades_gracefully(self):
        """GShard semantics: over-capacity tokens contribute zero; ample
        capacity drops nothing; capacities in between change only the
        dropped rows."""
        cfg, p, x = self._setup()
        y_full, _ = moe.moe_ffn(p, x, cfg, capacity_override=64)
        y_more, _ = moe.moe_ffn(p, x, cfg, capacity_override=128)
        np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_more),
                                   rtol=1e-5, atol=1e-5)  # no drops either way
        y_tight, _ = moe.moe_ffn(p, x, cfg, capacity_override=1)
        zero_rows = (np.abs(np.asarray(y_tight)).max(axis=-1) < 1e-7)
        full_zero = (np.abs(np.asarray(y_full)).max(axis=-1) < 1e-7)
        assert zero_rows.sum() > 0          # both slots dropped somewhere
        assert not full_zero.any()          # ample capacity drops nothing

    def test_gate_weights_convex(self):
        """Identical expert weights -> MoE == plain FFN of one expert
        (gates sum to 1 after normalization)."""
        cfg, p, x = self._setup()
        one = jax.tree_util.tree_map(lambda a: a, p)
        for name in ("w_gate", "w_up", "w_down"):
            one[name] = jnp.broadcast_to(p[name][:1], p[name].shape)
        y, _ = moe.moe_ffn(one, x, cfg, capacity_override=64)
        wg, wu, wd = one["w_gate"][0], one["w_up"][0], one["w_down"][0]
        ref = (jax.nn.silu(x @ wg) * (x @ wu)) @ wd
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                                   rtol=5e-3, atol=5e-3)

    def test_row_ranks(self):
        e = jnp.asarray([[1, 0, 1, 1, 0]])
        ranks = moe._row_ranks(e, 4)
        np.testing.assert_array_equal(np.asarray(ranks), [[0, 0, 1, 2, 1]])


class TestKVQuant:
    """Per-entry int8 KV quantization round-trip (`_kv_quantize` /
    `_kv_dequantize`): the paged-decode kernel fuses this dequant into its
    VMEM pass, so the codec's corner cases are kernel corner cases."""

    def test_roundtrip_error_bound(self):
        rng = np.random.default_rng(0)
        t = jnp.asarray(rng.normal(size=(4, 3, 32)) * 10, jnp.bfloat16)
        q, scale = attention._kv_quantize(t)
        assert q.dtype == jnp.int8 and scale.shape == (4, 3, 1)
        back = attention._kv_dequantize(q, scale, jnp.float32)
        # symmetric rounding: error <= half a quantization step per entry
        err = np.abs(np.asarray(back) - np.asarray(t, np.float32))
        assert (err <= np.asarray(scale) / 2 + 1e-6).all()

    def test_zero_vector_is_exact(self):
        """An all-zero entry must quantize to codes 0 and dequantize back
        to exactly zero (the 1e-8 amax floor prevents 0/0, not accuracy)."""
        t = jnp.zeros((2, 1, 16), jnp.bfloat16)
        q, scale = attention._kv_quantize(t)
        assert (np.asarray(q) == 0).all()
        assert (np.asarray(scale) > 0).all()          # no divide-by-zero
        back = attention._kv_dequantize(q, scale, jnp.bfloat16)
        assert (np.asarray(back, np.float32) == 0.0).all()

    def test_max_magnitude_hits_127_and_survives(self):
        """The per-entry amax element maps to exactly ±127 and round-trips
        to its own value bit-for-bit (scale = amax/127 by construction)."""
        t = np.zeros((1, 1, 8), np.float32)
        t[0, 0, 0] = 96.0                              # the amax element
        t[0, 0, 1] = -96.0                             # symmetric extreme
        q, scale = attention._kv_quantize(jnp.asarray(t))
        assert np.asarray(q)[0, 0, 0] == 127
        assert np.asarray(q)[0, 0, 1] == -127
        np.testing.assert_allclose(np.asarray(scale)[0, 0, 0], 96.0 / 127.0,
                                   rtol=1e-6)
        back = np.asarray(attention._kv_dequantize(q, scale, jnp.float32))
        np.testing.assert_allclose(back[0, 0, :2], [96.0, -96.0], rtol=1e-6)

    @given(st.integers(0, 2 ** 31 - 1), st.sampled_from([1, 4, 16]))
    @settings(max_examples=25, deadline=None)
    def test_roundtrip_property(self, seed, magnitude):
        """Any bf16 entry round-trips within half a step of its per-entry
        scale, across magnitudes (property; conftest fallback API)."""
        rng = np.random.default_rng(seed)
        t = jnp.asarray(rng.normal(size=(3, 2, 24)) * magnitude,
                        jnp.bfloat16)
        q, scale = attention._kv_quantize(t)
        assert np.abs(np.asarray(q)).max() <= 127
        back = attention._kv_dequantize(q, scale, jnp.float32)
        err = np.abs(np.asarray(back) - np.asarray(t, np.float32))
        assert (err <= np.asarray(scale) / 2 + 1e-5 * magnitude).all()
