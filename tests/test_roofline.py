"""Analytic roofline model: internal consistency + cross-validation against
XLA cost_analysis on an UNROLLED reduced config (where while-body
undercounting doesn't apply)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED_ARCHS, SHAPES, get_config, reduced_config, shape_applicable
from repro.models import build_model
from repro.perf.roofline_model import (analytic_cell, forward_flops,
                                       kv_cache_bytes, roofline_terms,
                                       weight_bytes_total)


def _cost_analysis(compiled):
    """jax < 0.5 returns a per-device list from cost_analysis(); >= 0.5 a
    single dict."""
    ca = compiled.cost_analysis()
    return ca[0] if isinstance(ca, list) else ca


def test_terms_positive_all_cells():
    for a in ASSIGNED_ARCHS:
        cfg = get_config(a)
        for s, sh in SHAPES.items():
            if not shape_applicable(cfg, sh)[0]:
                continue
            cell = analytic_cell(a, s)
            assert cell.flops > 0 and cell.hbm_bytes > 0, (a, s)
            rt = roofline_terms(cell)
            assert 0 < rt["roofline_fraction"] <= 1.0, (a, s, rt)


def test_decode_is_memory_bound_for_dense():
    """Single-token decode against a deep cache must be memory-bound —
    the regime the paper's technique targets."""
    for a in ("chatglm3-6b", "qwen3-8b", "phi3-medium-14b"):
        rt = roofline_terms(analytic_cell(a, "decode_32k", quant="psi8"))
        assert rt["bottleneck"] == "memory", (a, rt)


def test_psi_reduces_memory_term():
    """The paper's claim, translated to TPU: PSI weight compression moves
    the decode memory roofline."""
    for a in ("qwen3-8b", "granite-34b"):
        t_bf16 = analytic_cell(a, "decode_32k", quant="none").hbm_bytes
        t_psi8 = analytic_cell(a, "decode_32k", quant="psi8").hbm_bytes
        t_psi5 = analytic_cell(a, "decode_32k", quant="psi5").hbm_bytes
        assert t_psi8 < t_bf16 and t_psi5 < t_psi8
        # weights dominate; the full-weight part shrinks 2x / 3.2x
        w = weight_bytes_total(get_config(a), "none")
        assert (t_bf16 - t_psi8) == pytest.approx(w / 2, rel=0.01)


def test_train_flops_near_6nd():
    """Train FLOPs ~= 4x fwd where fwd ~= 2*N*D + attention."""
    cfg = get_config("qwen3-8b")
    sh = SHAPES["train_4k"]
    fwd = forward_flops(cfg, sh.global_batch, sh.seq_len, "train")
    n = cfg.param_count() - cfg.vocab_size * cfg.d_model
    two_nd = 2 * n * sh.global_batch * sh.seq_len
    assert 0.9 < fwd / two_nd < 1.5   # attention + lm head overhead

    moe = get_config("qwen3-moe-30b-a3b")
    fwd_moe = forward_flops(moe, sh.global_batch, sh.seq_len, "train")
    n_act = moe.active_param_count() - moe.vocab_size * moe.d_model
    assert 0.8 < fwd_moe / (2 * n_act * sh.global_batch * sh.seq_len) < 2.0


def test_kv_cache_bytes_swa_bounded():
    mix = get_config("mixtral-8x22b")
    assert (kv_cache_bytes(mix, 1, 524_288)
            == kv_cache_bytes(mix, 1, mix.window))
    dense = get_config("qwen3-8b")
    assert kv_cache_bytes(dense, 1, 65_536) == 2 * kv_cache_bytes(dense, 1, 32_768)


def test_cross_validate_against_unrolled_hlo():
    """Ground truth check: on an UNROLLED reduced config (scan_layers=False,
    no remat), XLA's cost_analysis flops must match forward_flops within
    35 % (layout/padding slack).  This is what justifies using the analytic
    model instead of cost_analysis on scanned modules (DESIGN.md §7)."""
    cfg = reduced_config(get_config("qwen3-8b"),
                         scan_layers=False, remat=False,
                         d_model=128, d_ff=256, n_layers=2, vocab_size=512,
                         head_dim=32, n_heads=4, n_kv_heads=2)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 4, 128
    batch = {"tokens": jnp.zeros((B, S), jnp.int32)}

    def fwd(p, b):
        return model.forward(p, b)[0]

    compiled = jax.jit(fwd).lower(params, batch).compile()
    hlo_flops = _cost_analysis(compiled)["flops"]
    ours = forward_flops(cfg, B, S, "prefill")
    assert 0.65 < ours / hlo_flops < 1.35, (ours, hlo_flops)


def test_scan_undercount_demonstrated():
    """The reason the analytic model exists: the SAME model scanned reports
    far fewer FLOPs from cost_analysis than unrolled."""
    base = dict(d_model=128, d_ff=256, n_layers=8, vocab_size=512,
                head_dim=32, n_heads=4, n_kv_heads=2, remat=False)
    B, S = 2, 64
    batch = {"tokens": jnp.zeros((B, S), jnp.int32)}
    flops = {}
    for scan in (True, False):
        cfg = reduced_config(get_config("qwen3-8b"), scan_layers=scan, **base)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        compiled = jax.jit(
            lambda p, b: model.forward(p, b)[0]).lower(params, batch).compile()
        flops[scan] = _cost_analysis(compiled)["flops"]
    assert flops[True] < 0.55 * flops[False]
