"""SLO-scheduling subsystem tests (DESIGN.md §3 "SLO scheduling"): policy
ordering/aging/victim selection, --slo spec parsing, optimistic-reservation
growth, preemption accounting across admit -> preempt -> re-admit -> retire
(property-tested churn), the capacity_version/_hol_blocked audit for
non-retire frees, ITL metric regressions, and the end-to-end acceptance:
chunked + priority + preemptive serving is token-identical to the FIFO
baseline with preemptions observed and the decode step compiling once."""
import dataclasses
import random

import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config, reduced_config
from repro.launch.prefix_cache import PrefixCache
from repro.launch.scheduler import (BlockAllocator, Request, Scheduler,
                                    poisson_trace, summarize)
from repro.launch.serve import Server, parse_mesh_spec
from repro.launch.slo import (DEFAULT_CLASSES, SLOClass, SLOPolicy,
                              bursty_heavy_tail_trace, parse_slo_spec,
                              slo_report)
from repro.models import build_model


def _req(rid, arrival=0.0, prio=0, plen=4, max_new=4, name=""):
    return Request(rid=rid, prompt=np.arange(plen, dtype=np.int32),
                   max_new=max_new, arrival_s=arrival, priority=prio,
                   slo_class=name)


# ---------------------------------------------------------------------------
# Policy: ordering, aging, victims, parsing.
# ---------------------------------------------------------------------------
class TestPolicy:
    def test_priority_orders_admission(self):
        pol = SLOPolicy(aging_s=30.0)
        hi = _req(0, arrival=1.0, prio=0)
        lo = _req(1, arrival=0.0, prio=2)
        assert pol.sort_key(hi) < pol.sort_key(lo)

    def test_aging_prevents_starvation(self):
        """A batch request that has waited aging_s * (priority gap) longer
        outranks a fresh interactive one — the key is time-invariant, so
        this is decided purely by arrival times."""
        pol = SLOPolicy(aging_s=10.0)
        old_batch = _req(0, arrival=0.0, prio=2)
        # interactive arriving 20s+ later: gap * aging = 2 * 10
        young_hi = _req(1, arrival=21.0, prio=0)
        assert pol.sort_key(old_batch) < pol.sort_key(young_hi)
        barely = _req(2, arrival=19.0, prio=0)
        assert pol.sort_key(barely) < pol.sort_key(old_batch)

    def test_sort_key_time_invariant_ties_break_fifo(self):
        pol = SLOPolicy()
        a, b = _req(0, arrival=1.0), _req(1, arrival=1.0)
        assert pol.sort_key(a) < pol.sort_key(b)        # rid breaks the tie

    def test_victim_key_prefers_lowest_priority_youngest(self):
        pol = SLOPolicy()
        batch_young = _req(0, arrival=5.0, prio=2)
        batch_old = _req(1, arrival=0.0, prio=2)
        inter = _req(2, arrival=0.0, prio=0)
        victims = sorted([inter, batch_old, batch_young],
                         key=pol.victim_key)
        assert victims[-1] is batch_young               # LARGER = preferred

    def test_class_of_by_name_then_priority(self):
        pol = SLOPolicy()
        assert pol.class_of(_req(0, name="batch")).name == "batch"
        assert pol.class_of(_req(1, prio=1)).name == "standard"
        assert pol.class_of(_req(2, prio=9)) is None

    def test_mix_shape(self):
        pol = SLOPolicy()
        mix = pol.mix([1.0, 2.0, 3.0])
        assert [m[0] for m in mix] == ["interactive", "standard", "batch"]
        with pytest.raises(ValueError, match="weights"):
            pol.mix([1.0])

    def test_policy_validation(self):
        with pytest.raises(ValueError, match="at least one class"):
            SLOPolicy(())
        with pytest.raises(ValueError, match="duplicate"):
            SLOPolicy((DEFAULT_CLASSES[0], DEFAULT_CLASSES[0]))
        with pytest.raises(ValueError, match="aging_s"):
            SLOPolicy(aging_s=0.0)
        with pytest.raises(ValueError, match="reserve_frac"):
            SLOPolicy(reserve_frac=1.5)
        with pytest.raises(ValueError, match="deadlines"):
            SLOClass("x", 0, ttft_deadline_s=0.0, itl_deadline_s=1.0)

    def test_parse_slo_spec(self):
        assert parse_slo_spec("off") is None
        assert parse_slo_spec("") is None
        assert parse_slo_spec("none") is None
        pol = parse_slo_spec("default")
        assert tuple(c.name for c in pol.classes) == ("interactive",
                                                      "standard", "batch")
        pol = parse_slo_spec("rt:0:0.2:0.05,bulk:3:60:10@aging=7@reserve=0.5")
        assert pol.aging_s == 7.0 and pol.reserve_frac == 0.5
        assert pol.classes[1].priority == 3
        with pytest.raises(ValueError, match="knob"):
            parse_slo_spec("default@bogus=1")
        with pytest.raises(ValueError, match="class"):
            parse_slo_spec("name:only:three")


# ---------------------------------------------------------------------------
# Allocator: reservation growth + capacity_version audit.
# ---------------------------------------------------------------------------
class TestReservationGrowth:
    def test_grow_reserve(self):
        alloc = BlockAllocator(4)
        alloc.reserve(1, 1)
        alloc.alloc(1)
        assert alloc.reserved_of(1) == 0
        alloc.grow_reserve(1, 2)
        assert alloc.reserved_of(1) == 2
        with pytest.raises(ValueError, match="n > 0"):
            alloc.grow_reserve(1, 0)
        with pytest.raises(ValueError, match="no reservation"):
            alloc.grow_reserve(2, 1)
        with pytest.raises(ValueError, match="cannot grow"):
            alloc.grow_reserve(1, 4)

    def test_unref_free_bumps_capacity_version(self):
        """Regression (the _hol_blocked audit): a block freed by the
        prefix cache dropping its pin — NOT a request retiring — must
        still be observable through capacity_version, or a head-of-line
        blocked admission would never retry."""
        alloc = BlockAllocator(4)
        alloc.reserve(1, 1)
        blk = alloc.alloc(1)
        alloc.ref_block(blk)
        alloc.release(1)               # pin keeps the block alive
        v = alloc.capacity_version
        assert alloc.unref_block(blk)  # last ref -> freed
        assert alloc.capacity_version > v

    def test_reservation_refund_bumps_capacity_version(self):
        alloc = BlockAllocator(4)
        alloc.reserve(1, 3)
        v = alloc.capacity_version
        alloc.release(1)               # no blocks held, pure refund
        assert alloc.capacity_version > v

    def test_hol_blocked_admission_retries_after_preempt(self):
        """End-to-end memo audit: an admission blocked on blocks proceeds
        once preemption frees capacity (preempt releases blocks AND the
        reservation remainder, both bumping capacity_version)."""
        pol = SLOPolicy(aging_s=1000.0)
        runner = _req(0, arrival=0.0, prio=2, plen=4, max_new=4)
        urgent = _req(1, arrival=1.0, prio=0, plen=4, max_new=4)
        blocks = BlockAllocator(4)
        sched = Scheduler([runner, urgent], max_batch=2, blocks=blocks,
                          blocks_needed=lambda r: 3, policy=pol)
        sched.poll(0.0)
        assert [r for _, r in sched.admit(0.0)] == [runner]
        sched.poll(1.0)
        assert sched.admit(1.0) == []            # 3 > 4 - 3 reserved/held
        assert sched._hol_blocked is not None
        assert sched.admit(1.1) == []            # memo: no pointless retry
        sched.preempt(runner.slot, 2.0)
        admits = sched.admit(2.0)
        assert [r.rid for _, r in admits] == [1]  # urgent first (priority)
        assert runner in sched.waiting

    def test_every_free_path_bumps_capacity_version(self):
        """Audit that all block-freeing paths route through _decref:
        release, unref_block, and a fork's decref of the shared original
        all advance capacity_version when a block actually frees."""
        alloc = BlockAllocator(6)
        alloc.reserve(1, 2)
        b0 = alloc.alloc(1)
        v = alloc.capacity_version
        alloc.release(1)                         # frees b0 + refund
        assert alloc.capacity_version >= v + 2


# ---------------------------------------------------------------------------
# Scheduler: preemption accounting.
# ---------------------------------------------------------------------------
class TestPreemptionAccounting:
    def _sched(self, reqs, n_blocks=16, max_batch=2, policy=None,
               prefix=None):
        blocks = BlockAllocator(n_blocks)
        return Scheduler(reqs, max_batch, blocks=blocks,
                         blocks_needed=lambda r: 2, policy=policy,
                         prefix=prefix), blocks

    def test_queue_and_ttft_survive_preemption(self):
        req = _req(0, arrival=0.5, max_new=8)
        sched, _ = self._sched([req])
        sched.poll(1.0)
        sched.admit(1.0)
        assert req.queue_s == pytest.approx(0.5)
        req.emit(7, 1.2)
        assert req.ttft_s == pytest.approx(0.7)
        sched.preempt(req.slot, 2.0)
        assert req.preemptions == 1 and req.slot is None
        assert req.queue_s == pytest.approx(0.5)     # first-admission value
        sched.admit(3.0)                             # re-admitted much later
        assert req.queue_s == pytest.approx(0.5)     # ...and unchanged
        req.emit(9, 3.1)                             # restore emission
        assert req.ttft_s == pytest.approx(0.7)      # TTFT never resets
        assert req.tokens == [7, 9]

    def test_preempt_publishes_only_covered_tokens(self):
        """covered= caps the publish at the KV actually written: with
        covered=0 nothing is published (no stray pins), and the blocks
        all free."""
        bs = 4
        prefix = PrefixCache(bs, align_tokens=bs)
        req = _req(0, plen=8, max_new=4)
        blocks = BlockAllocator(8)
        sched = Scheduler([req], 1, blocks=blocks,
                          blocks_needed=lambda r: 3, prefix=prefix)
        sched.poll(0.0)
        sched.admit(0.0)
        for _ in range(2):
            blocks.alloc(req.rid)
        sched.preempt(req.slot, 1.0, covered=0)
        assert len(prefix) == 0
        assert blocks.free_count == 8

    def test_preempt_publish_enables_restore_hit(self):
        bs = 4
        prefix = PrefixCache(bs, align_tokens=bs)
        req = _req(0, plen=8, max_new=8)
        blocks = BlockAllocator(8)
        sched = Scheduler([req], 1, blocks=blocks,
                          blocks_needed=lambda r: 4, prefix=prefix)
        sched.poll(0.0)
        sched.admit(0.0)
        for _ in range(2):
            blocks.alloc(req.rid)
        req.emit(3, 0.5)                 # full_seq now 9 tokens, 2 blocks
        sched.preempt(req.slot, 1.0, covered=8)
        assert len(prefix) == 2          # both full blocks published
        admits = sched.admit(2.0)
        assert admits and admits[0][1] is req
        assert req.prefix_blocks and len(req.prefix_blocks) == 2
        assert req.prefix_hit_tokens == 8
        assert prefix.stats()["restores"] == 1
        assert prefix.stats()["restored_tokens"] == 8

    @given(st.integers(0, 10_000))
    @settings(max_examples=25, deadline=None)
    def test_accounting_churn_invariants(self, seed):
        """Random admit -> emit -> preempt -> re-admit -> retire churn:
        queue_s is pinned to the FIRST admission and non-negative, ttft_s
        is pinned to the first emission, latency_s >= ttft_s >= queue_s
        ordering holds where defined (all NaN until defined, never
        negative), preemption counts are exact, and after the trace drains
        (plus LRU drain) the allocator's free set is exactly the initial
        one."""
        rng = random.Random(seed)
        bs = 4
        n_blocks = rng.randint(10, 24)
        reqs = [Request(rid=i,
                        prompt=np.arange(rng.randint(1, 12),
                                         dtype=np.int32) + i,
                        max_new=rng.randint(1, 6),
                        arrival_s=round(rng.random() * 2, 3),
                        priority=rng.randint(0, 2))
                for i in range(rng.randint(1, 10))]
        pol = SLOPolicy(aging_s=rng.choice([0.5, 5.0, 50.0]))
        blocks = BlockAllocator(n_blocks)
        initial_free = sorted(b for pool in blocks._free for b in pool)
        prefix = PrefixCache(bs, align_tokens=bs)
        needed = lambda r: min(n_blocks,
                               len(r.full_seq) // bs + 2)   # worst case
        sched = Scheduler(reqs, max_batch=rng.randint(1, 3), blocks=blocks,
                          blocks_needed=needed, prefix=prefix, policy=pol)
        first_queue = {}
        first_ttft = {}
        now = 0.0
        guard = 0
        while not sched.done:
            guard += 1
            assert guard < 10_000, "churn failed to drain"
            now += 0.05 + rng.random() * 0.2
            sched.poll(now)
            for slot, req in sched.admit(now):
                # materialize the hit-exclusive remainder of the coverage
                have = len(req.prefix_blocks)
                want = min(needed(req), len(req.full_seq) // bs + 1)
                for _ in range(max(0, want - have)):
                    blocks.alloc(req.rid)
                if req.rid in first_queue:
                    assert req.queue_s == first_queue[req.rid]
                else:
                    first_queue[req.rid] = req.queue_s
                    assert req.queue_s >= 0
                req.emit(rng.randrange(100), now)   # first / restore token
                first_ttft.setdefault(req.rid, req.ttft_s)
                assert req.ttft_s == first_ttft[req.rid] >= 0
            for slot in list(sched.running):
                req = sched.running[slot]
                if len(req.tokens) >= req.max_new:
                    sched.retire(slot, now)
                    assert req.latency_s >= req.ttft_s >= req.queue_s >= 0
                elif rng.random() < 0.25:
                    before = req.preemptions
                    covered = (len(req.full_seq) // bs) * bs \
                        if rng.random() < 0.5 else 0
                    sched.preempt(slot, now, covered=covered)
                    assert req.preemptions == before + 1
                    assert np.isnan(req.latency_s)
                else:
                    req.emit(rng.randrange(100), now)
        assert len(sched.finished) == len(reqs)
        prefix.drain(blocks)
        assert sorted(b for pool in blocks._free
                      for b in pool) == initial_free
        assert all(c == 0 for c in blocks.refcount)


# ---------------------------------------------------------------------------
# Metrics: ITL regressions.
# ---------------------------------------------------------------------------
class TestITLMetrics:
    def test_zero_and_one_token_requests_contribute_no_gaps(self):
        r0 = _req(0)                                  # zero tokens
        r1 = _req(1)
        r1.emit(5, 1.0)                               # one token: no gap
        assert r0.itl_gaps.size == 0
        assert r1.itl_gaps.size == 0

    def test_summarize_itl_ignores_short_requests(self):
        """Regression: 0/1-token requests must contribute NOTHING to the
        ITL percentiles — zeros would fraudulently drag p50 down."""
        a = _req(0)
        for i, t in enumerate([0.0, 0.1, 0.2, 0.3]):
            a.emit(i, t)
        a.finish_s = 0.3
        short = _req(1)
        short.emit(9, 0.05)
        short.finish_s = 0.05
        with_short = summarize([a, short], wall_s=1.0)
        alone = summarize([a], wall_s=1.0)
        assert with_short["p50_itl_s"] == alone["p50_itl_s"] == \
            pytest.approx(0.1)
        assert with_short["p99_itl_s"] == alone["p99_itl_s"]

    def test_summarize_no_requests_has_itl_keys(self):
        s = summarize([], wall_s=0.0)
        assert s["p50_itl_s"] == 0.0 and s["p99_itl_s"] == 0.0
        assert s["preemptions"] == 0

    def test_slo_report_attainment(self):
        pol = SLOPolicy()
        ok = _req(0, name="interactive")
        ok.arrival_s = 0.0
        for i, t in enumerate([0.1, 0.15, 0.2]):
            ok.emit(i, t)
        late = _req(1, name="interactive")
        late.arrival_s = 0.0
        late.emit(7, 2.0)                  # blows the 0.5s TTFT deadline
        unclassed = _req(2, prio=9)
        rep = slo_report([ok, late, unclassed], pol)
        ic = rep["interactive"]
        assert ic["n_requests"] == 2
        assert ic["ttft_attainment"] == pytest.approx(0.5)
        assert ic["itl_attainment"] == 1.0          # gaps all 0.05
        assert rep["batch"]["n_requests"] == 0
        assert rep["batch"]["ttft_attainment"] == 1.0


# ---------------------------------------------------------------------------
# Traces.
# ---------------------------------------------------------------------------
class TestTraces:
    def test_poisson_priority_mix_deterministic(self):
        mix = SLOPolicy().mix([1.0, 1.0, 2.0])
        a = poisson_trace(32, rate_rps=10, prompt_len=8, max_new=4,
                          vocab_size=100, seed=3, priority_mix=mix)
        b = poisson_trace(32, rate_rps=10, prompt_len=8, max_new=4,
                          vocab_size=100, seed=3, priority_mix=mix)
        assert [(r.priority, r.slo_class) for r in a] == \
            [(r.priority, r.slo_class) for r in b]
        assert {r.slo_class for r in a} <= {"interactive", "standard",
                                            "batch"}
        assert len({r.priority for r in a}) > 1

    def test_poisson_priority_mix_validation(self):
        with pytest.raises(ValueError, match="non-empty"):
            poisson_trace(4, rate_rps=10, prompt_len=8, max_new=4,
                          vocab_size=100, priority_mix=[])
        with pytest.raises(ValueError, match="weights"):
            poisson_trace(4, rate_rps=10, prompt_len=8, max_new=4,
                          vocab_size=100, priority_mix=[("a", 0, -1.0)])

    def test_bursty_trace_shape_and_determinism(self):
        mix = SLOPolicy().mix([1.0, 1.0, 1.0])
        a = bursty_heavy_tail_trace(16, vocab_size=100, seed=5,
                                    burst_size=4, mix=mix)
        b = bursty_heavy_tail_trace(16, vocab_size=100, seed=5,
                                    burst_size=4, mix=mix)
        assert [tuple(r.prompt) for r in a] == [tuple(r.prompt) for r in b]
        assert [r.arrival_s for r in a] == [r.arrival_s for r in b]
        # bursts: 4 groups separated by the burst gap
        gaps = np.diff([r.arrival_s for r in a])
        assert (gaps >= 0.5 - 1e-9).sum() == 3
        assert {len(r.prompt) for r in a} <= {8, 56}
        with pytest.raises(ValueError, match="long_frac"):
            bursty_heavy_tail_trace(4, vocab_size=100, seed=0,
                                    long_frac=1.5)
        with pytest.raises(ValueError, match="n_requests"):
            bursty_heavy_tail_trace(0, vocab_size=100, seed=0)


# ---------------------------------------------------------------------------
# Engine acceptance: token identity FIFO vs SLO+chunk+preemption.
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def qwen_setup():
    cfg = reduced_config(get_config("qwen3-8b"))
    model = build_model(cfg)
    params = model.quantize(model.init(jax.random.PRNGKey(0)), 8)
    cfg = dataclasses.replace(cfg, quant_mode="psi8")
    return cfg, params


_POLICY_SPEC = "default@aging=5@reserve=0.1"


def _bursty(cfg, n=16, seed=7):
    pol = parse_slo_spec(_POLICY_SPEC)
    return bursty_heavy_tail_trace(
        n, vocab_size=cfg.vocab_size, seed=seed, burst_size=8,
        burst_gap_s=0.3, long_frac=0.6, mix=pol.mix([3.0, 2.0, 1.0]))


class TestSLOServing:
    def test_requires_paged_and_rope(self, qwen_setup):
        cfg, params = qwen_setup
        dense = dataclasses.replace(cfg, cache_layout="dense")
        with pytest.raises(ValueError, match="paged"):
            Server(dense, params, max_batch=2, max_seq=64,
                   slo=parse_slo_spec("default"))
        with pytest.raises(ValueError, match="paged"):
            Server(dense, params, max_batch=2, max_seq=64, prefill_chunk=16)
        nope = dataclasses.replace(cfg, rope="sinusoidal")
        with pytest.raises(ValueError, match="RoPE"):
            Server(nope, params, max_batch=2, max_seq=64, prefill_chunk=16)

    def test_chunk_rounds_to_grid(self, qwen_setup):
        cfg, params = qwen_setup
        srv = Server(cfg, params, max_batch=2, max_seq=64, prefill_chunk=5)
        assert srv.prefill_chunk == 16     # lcm(block 16, bucket 16)
        with pytest.raises(ValueError, match=">= 0"):
            Server(cfg, params, max_batch=2, max_seq=64, prefill_chunk=-1)

    def test_chunked_prefill_token_identical(self, qwen_setup):
        """Chunked-only (no SLO): a long prompt split into 16-token pieces
        interleaved with decode emits exactly the unchunked tokens, decode
        still compiling once."""
        cfg, params = qwen_setup
        trace = lambda: poisson_trace(6, rate_rps=500, prompt_len=56,
                                      max_new=10, min_new=10,
                                      vocab_size=cfg.vocab_size, seed=2)
        plain = Server(cfg, params, max_batch=2, max_seq=96)
        chunked = Server(cfg, params, max_batch=2, max_seq=96,
                         prefill_chunk=16)
        d0, s0 = plain.serve(trace(), continuous=True)
        d1, s1 = chunked.serve(trace(), continuous=True)
        toks = lambda done: {r.rid: tuple(r.tokens) for r in done}
        assert toks(d0) == toks(d1)
        assert s1["prefill_chunks"] > 0
        assert s1["decode_compiles"] == 1
        assert s1["blocks_free_end"] == s1["n_blocks"]
        # accounting: chunked pieces forward the same real token count
        assert s1["prefilled_tokens"] == s0["prefilled_tokens"]

    def test_slo_preemptive_serving_token_identical(self, qwen_setup):
        """Acceptance: the bursty heavy-tail trace on a deliberately tight
        pool serves token-identically under --slo + --prefill-chunk vs
        the FIFO baseline, with preemptions AND restores observed, the
        decode step compiling exactly once, and zero block leakage."""
        cfg, params = qwen_setup
        pol = parse_slo_spec(_POLICY_SPEC)
        fifo = Server(cfg, params, max_batch=4, max_seq=112, n_blocks=8)
        slo = Server(cfg, params, max_batch=4, max_seq=112, n_blocks=8,
                     prefill_chunk=16, slo=pol)
        d0, s0 = fifo.serve(_bursty(cfg), continuous=True)
        d1, s1 = slo.serve(_bursty(cfg), continuous=True)
        toks = lambda done: {r.rid: tuple(r.tokens) for r in done}
        assert toks(d0) == toks(d1)
        assert s1["preemptions"] > 0
        assert s1["prefix_cache"]["restores"] > 0
        assert s1["prefix_cache"]["restored_tokens"] > 0
        assert s1["decode_compiles"] == 1
        assert s1["blocks_free_end"] == s1["n_blocks"]
        assert s0["blocks_free_end"] == s0["n_blocks"]
        rep = s1["slo"]["classes"]
        assert sum(c["n_requests"] for c in rep.values()) == 16
        assert sum(c["preemptions"] for c in rep.values()) == \
            s1["preemptions"]

    def test_slo_with_prefix_cache_on_token_identical(self, qwen_setup):
        """SLO mode composes with --prefix-cache on (shared lookups + swap
        restores through ONE cache) and stays token-identical."""
        cfg, params = qwen_setup
        pcfg = dataclasses.replace(cfg, prefix_cache=True)
        fifo = Server(cfg, params, max_batch=4, max_seq=112, n_blocks=8)
        slo = Server(pcfg, params, max_batch=4, max_seq=112, n_blocks=8,
                     prefill_chunk=16, slo=parse_slo_spec(_POLICY_SPEC))
        d0, _ = fifo.serve(_bursty(cfg, n=12), continuous=True)
        d1, s1 = slo.serve(_bursty(cfg, n=12), continuous=True)
        toks = lambda done: {r.rid: tuple(r.tokens) for r in done}
        assert toks(d0) == toks(d1)
        assert s1["blocks_free_end"] == s1["n_blocks"]

    @pytest.mark.skipif(len(jax.devices()) < 8,
                        reason="needs 8 devices (CI distributed leg forces "
                               "--xla_force_host_platform_device_count=8)")
    def test_sharded_mesh_token_identical(self, qwen_setup):
        """SLO + chunked + preemptive serving on a (4,2) mesh (slots and
        blocks partitioned over the data axis) emits exactly the
        single-device FIFO tokens, decode still compiling once."""
        cfg, params = qwen_setup
        fifo = Server(cfg, params, max_batch=4, max_seq=112, n_blocks=8)
        meshed = Server(cfg, params, max_batch=4, max_seq=112, n_blocks=8,
                        prefill_chunk=16, slo=parse_slo_spec(_POLICY_SPEC),
                        mesh=parse_mesh_spec("4x2"))
        d0, _ = fifo.serve(_bursty(cfg), continuous=True)
        d1, s1 = meshed.serve(_bursty(cfg), continuous=True)
        toks = lambda done: {r.rid: tuple(r.tokens) for r in done}
        assert toks(d0) == toks(d1)
        assert s1["decode_compiles"] == 1
        assert s1["slot_shards"] == 4
        assert s1["blocks_free_end"] == s1["n_blocks"]
