"""Differential fuzz harness for the fused paged-decode attention kernel.

Three implementations of the same read-side contract live in
``repro.kernels.paged_attention``:

  * ``paged_attention_ref``     — the pure-XLA oracle (the token-identity
                                  reference; the exact math of the pre-kernel
                                  gather path);
  * ``paged_attention_gather``  — the dense-gather GPU fast path;
  * ``paged_attention_pallas``  — the flash-decode Pallas kernel (tested in
                                  interpret mode: the kernel body runs on CPU).

The property tests drive randomized block tables (holes / −1 entries,
permuted physical blocks, inactive all-−1 slots, stale garbage in every
unreferenced pool location, positions at block boundaries 0 / bs−1 / bs)
and assert kernel ≡ oracle ≡ gather to fp32 accumulation-order tolerance —
and *exactly* for the masking pattern: rewriting every causally-invisible
pool entry must not change a single output bit.

Also here: the scatter-overflow regression (a position past the block
table's extent must write to the slot's scratch block, never clamp into
the last logical block) and the routed-block-vs-legacy-gather-path
equivalence that pins serving token identity across the PR.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config, reduced_config
from repro.kernels import ops
from repro.kernels import paged_attention as pa
from repro.models import attention

BS = 4            # block size of the synthetic pools
HQ, HKV, HD = 8, 2, 16


# ---------------------------------------------------------------------------
# Randomized case construction.
# ---------------------------------------------------------------------------
def _case(seed, B, n_bt, mode):
    """Pools full of garbage everywhere; tables with permuted physical
    blocks, holes, and (sometimes) an inactive slot; boundary-heavy
    positions.  mode in {"f32", "bf16", "int8"}."""
    rng = np.random.default_rng(seed)
    N = B * n_bt + B                                  # + per-slot scratch
    act = jnp.float32 if mode == "f32" else jnp.bfloat16
    q = jnp.asarray(rng.normal(size=(B, HQ, HD)), act)
    if mode == "int8":
        kp = jnp.asarray(rng.integers(-127, 128, size=(N, BS, HKV, HD)),
                         jnp.int8)
        vp = jnp.asarray(rng.integers(-127, 128, size=(N, BS, HKV, HD)),
                         jnp.int8)
        ks = jnp.asarray(rng.uniform(1e-3, 0.05, size=(N, BS, HKV, 1)),
                         jnp.float32)
        vs = jnp.asarray(rng.uniform(1e-3, 0.05, size=(N, BS, HKV, 1)),
                         jnp.float32)
    else:
        kp = jnp.asarray(rng.normal(size=(N, BS, HKV, HD)), act)
        vp = jnp.asarray(rng.normal(size=(N, BS, HKV, HD)), act)
        ks = vs = None
    bt = rng.permutation(B * n_bt).astype(np.int32).reshape(B, n_bt)
    bt = np.where(rng.random((B, n_bt)) < 0.3, -1, bt)     # holes
    if B > 1 and rng.random() < 0.5:
        bt[rng.integers(B)] = -1                           # inactive slot
    bounds = np.array([0, BS - 1, BS, n_bt * BS - 1])
    pos = np.where(rng.random(B) < 0.5,
                   rng.choice(bounds, size=B),
                   rng.integers(0, n_bt * BS, size=B)).astype(np.int32)
    return q, kp, vp, jnp.asarray(bt), jnp.asarray(pos), ks, vs


def _visible_rows(bt, pos):
    """Slots with at least one causally visible key (offset 0 of some
    allocated logical block j with j*bs <= pos)."""
    bt, pos = np.asarray(bt), np.asarray(pos)
    j = np.arange(bt.shape[1]) * BS
    return ((bt >= 0) & (j[None, :] <= pos[:, None])).any(axis=1)


def _visible_pool_mask(bt, pos, N):
    """(N, bs) bool: pool entries that are causally visible to any slot."""
    vis = np.zeros((N, BS), bool)
    bt, pos = np.asarray(bt), np.asarray(pos)
    for b in range(bt.shape[0]):
        for j in range(bt.shape[1]):
            pb = int(bt[b, j])
            if pb >= 0:
                upto = min(BS, int(pos[b]) - j * BS + 1)
                if upto > 0:
                    vis[pb, :upto] = True
    return vis


def _all(q, kp, vp, bt, pos, ks, vs):
    ref = np.asarray(pa.paged_attention_ref(q, kp, vp, bt, pos, ks, vs),
                     np.float32)
    gat = np.asarray(pa.paged_attention_gather(q, kp, vp, bt, pos, ks, vs),
                     np.float32)
    ker = np.asarray(pa.paged_attention_pallas(q, kp, vp, bt, pos, ks, vs,
                                               interpret=True), np.float32)
    return ref, gat, ker


# ---------------------------------------------------------------------------
# The differential property.
# ---------------------------------------------------------------------------
@given(st.integers(0, 2 ** 31 - 1), st.sampled_from([1, 2, 4]),
       st.sampled_from([2, 3, 6]), st.sampled_from(["f32", "bf16", "int8"]))
@settings(max_examples=12, deadline=None)
def test_kernel_oracle_gather_agree(seed, B, n_bt, mode):
    q, kp, vp, bt, pos, ks, vs = _case(seed, B, n_bt, mode)
    ref, gat, ker = _all(q, kp, vp, bt, pos, ks, vs)
    rows = _visible_rows(bt, pos)
    tol = (dict(rtol=1e-5, atol=1e-4) if mode == "f32"
           else dict(rtol=4e-2, atol=4e-2))
    np.testing.assert_allclose(ker[rows], ref[rows], **tol)
    np.testing.assert_allclose(gat[rows], ref[rows], **tol)
    # inactive / fully-masked slots: the kernel's contract is exact zero
    assert (ker[~rows] == 0.0).all()


@given(st.integers(0, 2 ** 31 - 1), st.sampled_from([2, 4]),
       st.sampled_from([2, 4]), st.sampled_from(["bf16", "int8"]))
@settings(max_examples=8, deadline=None)
def test_masking_pattern_is_exact(seed, B, n_bt, mode):
    """Rewriting every causally-invisible pool entry (stale rows past pos,
    unreferenced blocks, scratch blocks, holes) changes no output bit in
    either the oracle or the kernel."""
    q, kp, vp, bt, pos, ks, vs = _case(seed, B, n_bt, mode)
    ref0, _, ker0 = _all(q, kp, vp, bt, pos, ks, vs)
    N = kp.shape[0]
    vis = _visible_pool_mask(bt, pos, N)[:, :, None, None]
    rng = np.random.default_rng(seed ^ 0x5EED)
    if mode == "int8":
        garbage = lambda t: jnp.asarray(np.where(
            vis, np.asarray(t), rng.integers(-127, 128, t.shape)), t.dtype)
        s_garbage = lambda t: jnp.asarray(np.where(
            vis, np.asarray(t), rng.uniform(1e-3, 0.05, t.shape)), t.dtype)
        ks2, vs2 = s_garbage(ks), s_garbage(vs)
    else:
        garbage = lambda t: jnp.asarray(np.where(
            vis, np.asarray(t, np.float32), rng.normal(size=t.shape)),
            t.dtype)
        ks2, vs2 = None, None
    ref1, _, ker1 = _all(q, garbage(kp), garbage(vp), bt, pos, ks2, vs2)
    # the oracle's fully-masked rows softmax uniformly over garbage (their
    # output is discarded host-side), so its bit-stability claim covers
    # visible rows; the kernel's contract (exact zero) holds everywhere.
    rows = _visible_rows(bt, pos)
    np.testing.assert_array_equal(ref0[rows], ref1[rows])
    np.testing.assert_array_equal(ker0, ker1)


def test_boundary_positions_exhaustive():
    """pos at exactly 0, bs−1, bs, and the last table entry: every backend
    attends to exactly pos+1 keys (checked against a hand-built dense
    reference)."""
    rng = np.random.default_rng(0)
    n_bt = 3
    N = n_bt + 1
    kp = jnp.asarray(rng.normal(size=(N, BS, HKV, HD)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(N, BS, HKV, HD)), jnp.float32)
    q = jnp.asarray(rng.normal(size=(1, HQ, HD)), jnp.float32)
    bt = jnp.asarray([[2, 0, 1]], jnp.int32)          # permuted blocks
    for p in (0, BS - 1, BS, n_bt * BS - 1):
        pos = jnp.asarray([p], jnp.int32)
        ref, gat, ker = _all(q, kp, vp, bt, pos, None, None)
        # dense reference over the logically ordered, truncated KV
        order = np.asarray(bt)[0]
        kd = np.asarray(kp)[order].reshape(n_bt * BS, HKV, HD)[:p + 1]
        vd = np.asarray(vp)[order].reshape(n_bt * BS, HKV, HD)[:p + 1]
        qn = np.asarray(q)[0].reshape(HKV, HQ // HKV, HD)
        s = np.einsum("hgd,khd->hgk", qn, kd) * (HD ** -0.5)
        w = np.exp(s - s.max(-1, keepdims=True))
        w /= w.sum(-1, keepdims=True)
        want = np.einsum("hgk,khd->hgd", w, vd).reshape(HQ, HD)
        for got in (ref, gat, ker):
            np.testing.assert_allclose(got[0], want, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Routing (kernels.ops contract).
# ---------------------------------------------------------------------------
class TestRouting:
    def test_cpu_default_is_oracle(self, monkeypatch):
        monkeypatch.delenv("REPRO_PAGED_ATTN", raising=False)
        monkeypatch.delenv("REPRO_FORCE_INTERPRET", raising=False)
        monkeypatch.setattr(ops, "_backend", lambda: "cpu")
        assert ops.paged_attn_route() == "ref"

    def test_backend_routing_is_explicit(self, monkeypatch):
        monkeypatch.delenv("REPRO_PAGED_ATTN", raising=False)
        monkeypatch.setattr(ops, "_backend", lambda: "tpu")
        assert ops.paged_attn_route() == "pallas"
        monkeypatch.setattr(ops, "_backend", lambda: "gpu")
        assert ops.paged_attn_route() == "gather"
        monkeypatch.setattr(ops, "_backend", lambda: "cpu")
        monkeypatch.setenv("REPRO_FORCE_INTERPRET", "1")
        assert ops.paged_attn_route() == "interpret"

    def test_env_override_and_loud_failure(self, monkeypatch):
        monkeypatch.setenv("REPRO_PAGED_ATTN", "gather")
        assert ops.paged_attn_route() == "gather"
        monkeypatch.setenv("REPRO_PAGED_ATTN", "vliw")
        with pytest.raises(ValueError, match="REPRO_PAGED_ATTN"):
            ops.paged_attn_route()

    def test_routed_interpret_matches_oracle(self, monkeypatch):
        q, kp, vp, bt, pos, ks, vs = _case(7, 2, 3, "bf16")
        monkeypatch.delenv("REPRO_PAGED_ATTN", raising=False)
        monkeypatch.delenv("REPRO_FORCE_INTERPRET", raising=False)
        ref = ops.paged_decode_attention(q, kp, vp, bt, pos, ks, vs)
        monkeypatch.setenv("REPRO_FORCE_INTERPRET", "1")
        ker = ops.paged_decode_attention(q, kp, vp, bt, pos, ks, vs)
        rows = _visible_rows(bt, pos)
        np.testing.assert_allclose(
            np.asarray(ref, np.float32)[rows],
            np.asarray(ker, np.float32)[rows], rtol=4e-2, atol=4e-2)


# ---------------------------------------------------------------------------
# Block-level: routed read side vs the pre-PR gather path, and the
# scatter-overflow regression.
# ---------------------------------------------------------------------------
def _cfg(**kw):
    return reduced_config(get_config("qwen3-8b"), **kw)


def _legacy_paged_block(p, x, cfg, positions, cache, block_tables,
                        active=None):
    """The pre-kernel paged decode block, verbatim (PR 4): masked scatter
    (with its pos//bs clip) + dense gather + sdpa read.  The routed block
    must stay token-identical to this on in-range positions."""
    q, k_new, v_new = attention._project_qkv(p, x, cfg, positions)
    pos1d = positions[:, 0] if positions.ndim == 3 else positions
    B = x.shape[0]
    N, bs = cache["k"].shape[0], cache["k"].shape[1]
    n_bt = block_tables.shape[1]
    pos = pos1d[:, 0]
    li = jnp.clip(pos // bs, 0, n_bt - 1)
    off = pos % bs
    pb = jnp.take_along_axis(block_tables, li[:, None], axis=1)[:, 0]
    ok = pb >= 0
    if active is not None:
        ok = ok & active
    dest = jnp.where(ok, pb, N - B + jnp.arange(B, dtype=pb.dtype))
    if "k_scale" in cache:
        kq, ks = attention._kv_quantize(k_new[:, 0])
        vq, vs = attention._kv_quantize(v_new[:, 0])
        new_cache = {
            "k": cache["k"].at[dest, off].set(kq),
            "v": cache["v"].at[dest, off].set(vq),
            "k_scale": cache["k_scale"].at[dest, off].set(ks),
            "v_scale": cache["v_scale"].at[dest, off].set(vs),
        }
    else:
        new_cache = {
            "k": cache["k"].at[dest, off].set(
                k_new[:, 0].astype(cache["k"].dtype)),
            "v": cache["v"].at[dest, off].set(
                v_new[:, 0].astype(cache["v"].dtype)),
        }
    safe = jnp.maximum(block_tables, 0)

    def gather(pool):
        g = pool[safe]
        return g.reshape(B, n_bt * bs, *pool.shape[2:])

    if "k_scale" in new_cache:
        k = attention._kv_dequantize(gather(new_cache["k"]),
                                     gather(new_cache["k_scale"]), x.dtype)
        v = attention._kv_dequantize(gather(new_cache["v"]),
                                     gather(new_cache["v_scale"]), x.dtype)
    else:
        k, v = gather(new_cache["k"]), gather(new_cache["v"])
    base = (jnp.arange(n_bt, dtype=jnp.int32)[None, :, None] * bs
            + jnp.arange(bs, dtype=jnp.int32)[None, None, :])
    k_pos = jnp.where(block_tables[:, :, None] >= 0, base,
                      -1).reshape(B, n_bt * bs)
    o = attention.sdpa(q, k, v, pos1d, k_pos, causal=True, window=0)
    from repro.quant import linear
    y = linear(p["wo"], o.reshape(B, 1, -1), cfg.quant_mode)
    return y, new_cache


@pytest.mark.parametrize("kv_quant", ["none", "int8"])
def test_routed_block_matches_legacy_gather_path(kv_quant):
    """End-to-end block output: the routed kernel read side reproduces the
    pre-PR XLA gather path bit-for-bit on the CPU oracle route (this is
    what keeps served tokens identical across the PR)."""
    cfg = _cfg(kv_quant=kv_quant)
    p = attention.init_attention(cfg, jax.random.PRNGKey(0))
    B, n_bt, bs = 2, 4, cfg.cache_block_size
    N = B * n_bt + B
    cache = attention.init_paged_kv_cache(cfg, N, bs)
    rng = np.random.default_rng(3)
    bt = jnp.asarray(rng.permutation(B * n_bt).reshape(B, n_bt), jnp.int32)
    bt = bt.at[0, 3].set(-1)
    x = jnp.asarray(rng.normal(size=(B, 1, cfg.d_model)), jnp.float32)
    positions = jnp.asarray([[bs + 1], [0]], jnp.int32)
    active = jnp.asarray([True, True])
    y_new, c_new = attention.paged_decode_attention_block(
        p, x, cfg, positions, cache, bt, active=active)
    y_old, c_old = _legacy_paged_block(p, x, cfg, positions, cache, bt,
                                       active=active)
    for leaf in c_new:
        np.testing.assert_array_equal(np.asarray(c_new[leaf]),
                                      np.asarray(c_old[leaf]))
    np.testing.assert_array_equal(np.asarray(y_new, np.float32),
                                  np.asarray(y_old, np.float32))


@pytest.mark.parametrize("kv_quant", ["none", "int8"])
def test_scatter_overflow_writes_scratch_not_last_block(kv_quant):
    """Regression: pos//bs >= n_bt used to clip into the LAST logical
    block, scatter-corrupting a physical block owned by another token.
    Overflow must land in the slot's scratch block instead."""
    cfg = _cfg(kv_quant=kv_quant)
    p = attention.init_attention(cfg, jax.random.PRNGKey(1))
    B, n_bt, bs = 2, 2, cfg.cache_block_size
    N = B * n_bt + B
    cache = attention.init_paged_kv_cache(cfg, N, bs)
    # sentinel contents so any corruption is visible
    cache = {k: (v + 1).astype(v.dtype) for k, v in cache.items()}
    bt = jnp.asarray([[0, 1], [2, 3]], jnp.int32)      # fully allocated
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.normal(size=(B, 1, cfg.d_model)), jnp.float32)
    overflow = n_bt * bs                               # first out-of-range pos
    positions = jnp.asarray([[overflow], [overflow + 3]], jnp.int32)
    _, c = attention.paged_decode_attention_block(
        p, x, cfg, positions, cache, bt,
        active=jnp.asarray([True, True]))
    for leaf in c:
        got, before = np.asarray(c[leaf]), np.asarray(cache[leaf])
        # every table-owned block is untouched (the old bug wrote into the
        # last logical block's physical block at offset pos % bs)
        np.testing.assert_array_equal(got[:B * n_bt], before[:B * n_bt])
        # the write landed in each slot's own scratch block
        for b in range(B):
            off = int(np.asarray(positions)[b, 0]) % bs
            assert not np.array_equal(got[N - B + b, off],
                                      before[N - B + b, off]), (leaf, b)


def test_inactive_slots_do_not_write_anywhere_owned():
    """active=False rows route their scatter to scratch even with a valid
    table entry (masked-decode contract, unchanged by the kernel PR)."""
    cfg = _cfg()
    p = attention.init_attention(cfg, jax.random.PRNGKey(2))
    B, n_bt, bs = 2, 2, cfg.cache_block_size
    N = B * n_bt + B
    cache = attention.init_paged_kv_cache(cfg, N, bs)
    cache = {k: (v + 1).astype(v.dtype) for k, v in cache.items()}
    bt = jnp.asarray([[0, 1], [2, 3]], jnp.int32)
    x = jnp.asarray(np.random.default_rng(8).normal(size=(B, 1, cfg.d_model)),
                    jnp.float32)
    positions = jnp.asarray([[1], [1]], jnp.int32)
    _, c = attention.paged_decode_attention_block(
        p, x, cfg, positions, cache, bt,
        active=jnp.asarray([False, True]))
    np.testing.assert_array_equal(np.asarray(c["k"])[:B * n_bt][[0, 2]][0],
                                  np.asarray(cache["k"])[:B * n_bt][[0, 2]][0])
    # row 0 inactive: its blocks 0/1 untouched; row 1 active: block 2 off 1
    assert np.array_equal(np.asarray(c["k"])[0], np.asarray(cache["k"])[0])
    assert np.array_equal(np.asarray(c["k"])[1], np.asarray(cache["k"])[1])
    assert not np.array_equal(np.asarray(c["k"])[2, 1],
                              np.asarray(cache["k"])[2, 1])
