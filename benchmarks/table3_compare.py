"""Paper Table III: comparison with Eyeriss / ConvNet / DSIP
(MACs, power, frequency, GMACs, GMACs/W)."""
from __future__ import annotations

import time

from repro.core import baselines as bl


def run():
    t0 = time.time()
    rows = bl.table3_rows()
    print("Table III — comparison with prior works:")
    hdr = (f"  {'accel':12s} {'w-bits':>6s} {'a-bits':>6s} {'MACs':>6s} "
           f"{'mW':>7s} {'MHz':>5s} {'GMACs':>7s} {'GMACs/W':>8s}")
    print(hdr)
    for r in rows:
        print(f"  {r['name']:12s} {r['weight_bits']:>6} {r['act_bits']:>6} "
              f"{r['n_macs']:>6} {r['power_mw']:>7.1f} {r['freq_mhz']:>5.0f} "
              f"{r['gmacs']:>7.1f} {r['gmacs_per_w']:>8.1f}")
    tma5 = next(r for r in rows if r["name"] == "TMA (INT5)")
    conv = next(r for r in rows if r["name"] == "ConvNet")
    ratio = tma5["gmacs_per_w"] / conv["gmacs_per_w"]
    print(f"  TMA INT5 vs ConvNet efficiency: {ratio:.1f}x (paper ~12.7x)")
    us = (time.time() - t0) * 1e6
    return [("table3_compare", us,
             f"tma5={tma5['gmacs_per_w']:.0f}GMACs/W;vs_convnet={ratio:.1f}x")]


if __name__ == "__main__":
    run()
