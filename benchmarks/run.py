"""Benchmark harness: one module per paper table/figure + the roofline
reporter.  Prints ``name,us_per_call,derived`` CSV at the end.

  PYTHONPATH=src python -m benchmarks.run
"""
from __future__ import annotations

import time


def main() -> None:
    from benchmarks import (fig8_latency, fig9_sram, kernel_bench,
                            quant_sweep, serve_bench, table1_quant,
                            table2_perf, table3_compare)
    from benchmarks.roofline import full_table

    rows = []
    for mod in (table1_quant, table2_perf, table3_compare, fig8_latency,
                fig9_sram, kernel_bench, serve_bench, quant_sweep):
        print(f"\n=== {mod.__name__} ===")
        rows.extend(mod.run())

    print("\n=== roofline (analytic, psi8 serving / bf16 train) ===")
    t0 = time.time()
    table = full_table("psi8")
    worst = None
    for r in table:
        if "skipped" in r:
            continue
        if worst is None or r["roofline_fraction"] < worst["roofline_fraction"]:
            worst = r
    n_cells = sum(1 for r in table if "skipped" not in r)
    print(f"  {n_cells} runnable cells; worst roofline fraction: "
          f"{worst['arch']} x {worst['shape']} = {worst['roofline_fraction']:.3f}")
    rows.append(("roofline_table", (time.time() - t0) * 1e6,
                 f"cells={n_cells};worst={worst['roofline_fraction']:.3f}"))

    print("\nname,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
