"""Roofline reporter: analytic terms (repro.perf.roofline_model) joined with
the dry-run JSON (compile proof, memory_analysis, collective inventory).

  PYTHONPATH=src python -m benchmarks.roofline [--quant psi8] [--json out]
"""
from __future__ import annotations

import argparse
import json

from repro.configs import ASSIGNED_ARCHS, SHAPES, get_config, shape_applicable
from repro.perf.roofline_model import analytic_cell, roofline_terms


def full_table(quant: str = "psi8", chips: int = 256):
    rows = []
    for a in ASSIGNED_ARCHS:
        cfg = get_config(a)
        for s in SHAPES:
            ok, why = shape_applicable(cfg, SHAPES[s])
            if not ok:
                rows.append({"arch": a, "shape": s, "skipped": why})
                continue
            q = quant if SHAPES[s].kind != "train" else "none"
            cell = analytic_cell(a, s, quant=q, chips=chips)
            rt = roofline_terms(cell, chips=chips)
            rows.append({"arch": a, "shape": s, "quant": q,
                         "flops_per_dev": cell.flops / chips,
                         "hbm_bytes_per_dev": cell.hbm_bytes / chips,
                         "coll_bytes_per_dev": cell.coll_bytes_per_dev,
                         **rt})
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quant", default="psi8")
    ap.add_argument("--json", default=None)
    args = ap.parse_args()
    rows = full_table(args.quant)
    hdr = (f"{'arch':22s} {'shape':12s} {'comp_s':>9s} {'mem_s':>9s} "
           f"{'coll_s':>9s} {'bound':>11s} {'frac':>6s}")
    print(hdr)
    for r in rows:
        if "skipped" in r:
            print(f"{r['arch']:22s} {r['shape']:12s} SKIP ({r['skipped'][:50]}...)")
            continue
        print(f"{r['arch']:22s} {r['shape']:12s} {r['compute_s']:9.2e} "
              f"{r['memory_s']:9.2e} {r['collective_s']:9.2e} "
              f"{r['bottleneck']:>11s} {r['roofline_fraction']:6.2f}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
