"""Paper Fig. 9: Psum SRAM-access reduction vs Eyeriss (AlexNet, batch 1)."""
from __future__ import annotations

import time

from repro.core import baselines as bl, tma_model as tm


def run():
    t0 = time.time()
    layers = tm.alexnet_layers()
    print("Fig. 9 — Psum SRAM accesses (AlexNet, batch 1):")
    best_conv = best_fc = 0.0
    for l in layers:
        tma = tm.psum_sram_accesses_tma(l)
        ey = bl.EYERISS.psum_sram_accesses(l)
        red = ey / tma
        kind = "conv" if isinstance(l, tm.ConvLayer) else "fc"
        if kind == "conv":
            best_conv = max(best_conv, red)
        else:
            best_fc = max(best_fc, red)
        print(f"  {l.name:6s} TMA {tma:>10,}  Eyeriss {ey:>12,.0f}  "
              f"reduction {red:6.0f}x")
    print(f"  max reduction: conv {best_conv:.0f}x (paper ~74x), "
          f"fc {best_fc:.0f}x (paper ~240x)")
    us = (time.time() - t0) * 1e6
    return [("fig9_sram", us,
             f"conv_max={best_conv:.0f}x;fc_max={best_fc:.0f}x")]


if __name__ == "__main__":
    run()
