"""Microbenchmark of the psi_matmul kernels (CPU oracle path timing + the
analytic HBM-traffic advantage that is the kernel's reason to exist).

Wall-times here are CPU-oracle numbers (the container has no TPU); the
roofline-relevant quantities are analytic: the weight-byte column (bf16
2.0 B/w, PSI-INT8 1.0 B/w, PSI-INT5 0.625 B/w) and, for the decode-shaped
sweep (M in {1, 4, 8, 16} = active slots), the padded-MAC count the
small-M tile dispatch (``psi_matmul.pick_bm``) issues versus the fixed
128-row tile it replaced.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import psi
from repro.kernels import psi_matmul as pk
from repro.kernels import ref


def _time(fn, *args, iters=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / iters * 1e6


def run():
    rows = []
    M, K, N = 256, 2048, 2048
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(M, K)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(K, N)).astype(np.float32))
    q8 = psi.quantize_weights(w, 8, axis=0)
    q5 = psi.quantize_weights(w, 5, axis=0)
    planes = psi.pack_int5(q5.codes)

    f_bf16 = jax.jit(lambda x, w: x @ w)
    f_int8 = jax.jit(lambda x, c, s: ref.psi_matmul_int8_ref(x, c, s))
    f_int5 = jax.jit(lambda x, p, s: ref.psi_matmul_int5_ref(x, p, s))

    t_b = _time(f_bf16, x, w)
    t_8 = _time(f_int8, x, q8.codes, q8.scale.reshape(-1))
    t_5 = _time(f_int5, x, planes, q5.scale.reshape(-1))
    wb = K * N
    print(f"psi_matmul {M}x{K}x{N} (CPU oracle timings; bytes = HBM model):")
    print(f"  bf16      {t_b:9.0f} us   weight bytes {2.0 * wb / 1e6:7.2f} MB")
    print(f"  psi-int8  {t_8:9.0f} us   weight bytes {1.0 * wb / 1e6:7.2f} MB (2.0x less)")
    print(f"  psi-int5  {t_5:9.0f} us   weight bytes {0.625 * wb / 1e6:7.2f} MB (3.2x less)")
    rows.append(("kernel_bf16", t_b, f"bytes={2.0*wb:.0f}"))
    rows.append(("kernel_psi8", t_8, f"bytes={1.0*wb:.0f}"))
    rows.append(("kernel_psi5", t_5, f"bytes={0.625*wb:.0f}"))

    # Decode-shaped sweep: M = active decode slots.  Wall time is the CPU
    # oracle; the dispatch-relevant column is padded MACs — what the TPU
    # kernel grid actually issues with the old fixed bm=128 tile vs the
    # small-M tile ops.psi_matmul_2d now picks (>=2x fewer at M<=16 is the
    # acceptance bar; at M=1/f32 it is 16x).
    print(f"decode-shaped dispatch (K={K}, N={N}; M = active slots):")
    for M in (1, 4, 8, 16):
        xm = jnp.asarray(rng.normal(size=(M, K)).astype(np.float32))
        t_m = _time(f_int8, xm, q8.codes, q8.scale.reshape(-1))
        bm = pk.pick_bm(M, jnp.float32)
        macs_old = pk.padded_macs(M, K, N)            # fixed 128-row tile
        macs_new = pk.padded_macs(M, K, N, bm=bm)
        ratio = macs_old / macs_new
        print(f"  M={M:<3d} bm {pk.DEFAULT_BM}->{bm:<3d} "
              f"padded MACs {macs_old / 1e6:7.1f}M -> {macs_new / 1e6:6.1f}M "
              f"({ratio:4.1f}x fewer)  oracle {t_m:7.0f} us")
        rows.append((f"kernel_decode_m{M}", t_m,
                     f"bm={bm};padded_macs={macs_new};"
                     f"macs_vs_128tile={ratio:.1f}x"))
    return rows


if __name__ == "__main__":
    run()
