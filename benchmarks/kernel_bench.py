"""Microbenchmark of the psi_matmul + paged-attention kernels (CPU oracle
path timing + the analytic HBM-traffic advantage that is each kernel's
reason to exist).

Wall-times here are CPU-oracle numbers (the container has no TPU); the
roofline-relevant quantities are analytic: the weight-byte column (bf16
2.0 B/w, PSI-INT8 1.0 B/w, PSI-INT5 0.625 B/w) and, for the decode-shaped
sweep (M in {1, 4, 8, 16} = active slots), the padded-MAC count the
small-M tile dispatch (``psi_matmul.pick_bm``) issues versus the fixed
128-row tile it replaced.

The paged-decode sweep (B x n_bt x {bf16, int8} pools) reports, per
config, the bytes of dense gathered/dequantized temporaries the old read
path materialized per decode step per layer (``gathered_bytes_eliminated``
— the fused kernel's win), the pool bytes the kernel streams instead, the
oracle-vs-gather agreement, and (with ``--kernel-check``, the CI
kernel-bench leg) the interpret-mode Pallas kernel's max error against the
oracle.  ``python -m benchmarks.kernel_bench --out BENCH_kernel.json``
writes the machine-readable artifact CI asserts on.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import psi
from repro.kernels import paged_attention as pa
from repro.kernels import psi_matmul as pk
from repro.kernels import ref


def _time(fn, *args, iters=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / iters * 1e6


# paged-decode sweep geometry (reduced-config-scale heads, serving-scale
# block size); the traffic model is per decode step per layer.
PAGED_BS, PAGED_HQ, PAGED_HKV, PAGED_HD = 16, 8, 2, 64


def _paged_case(rng, B, n_bt, quantized):
    bs, hq, hkv, hd = PAGED_BS, PAGED_HQ, PAGED_HKV, PAGED_HD
    N = B * n_bt + B                                   # + per-slot scratch
    q = jnp.asarray(rng.normal(size=(B, hq, hd)), jnp.bfloat16)
    if quantized:
        kp = jnp.asarray(rng.integers(-127, 128, size=(N, bs, hkv, hd)),
                         jnp.int8)
        vp = jnp.asarray(rng.integers(-127, 128, size=(N, bs, hkv, hd)),
                         jnp.int8)
        ks = jnp.asarray(rng.uniform(1e-3, 0.05, size=(N, bs, hkv, 1)),
                         jnp.float32)
        vs = jnp.asarray(rng.uniform(1e-3, 0.05, size=(N, bs, hkv, 1)),
                         jnp.float32)
    else:
        kp = jnp.asarray(rng.normal(size=(N, bs, hkv, hd)), jnp.bfloat16)
        vp = jnp.asarray(rng.normal(size=(N, bs, hkv, hd)), jnp.bfloat16)
        ks = vs = None
    # permuted physical blocks, fully allocated, full-length decode (the
    # worst-case gather the kernel eliminates)
    bt = jnp.asarray(rng.permutation(B * n_bt).reshape(B, n_bt), jnp.int32)
    pos = jnp.full((B,), n_bt * bs - 1, jnp.int32)
    return q, kp, vp, bt, pos, ks, vs


def paged_sweep(kernel_check=False):
    """B x n_bt x pool-dtype sweep of the paged-decode read side.  Returns
    (csv_rows, json_records)."""
    rows, records = [], []
    bs, hkv, hd = PAGED_BS, PAGED_HKV, PAGED_HD
    print("paged-decode read side (CPU oracle vs dense gather; bytes = "
          "dense temporaries the fused kernel eliminates per step/layer):")
    for quantized in (False, True):
        pool = "int8" if quantized else "bf16"
        for n_bt in (4, 16, 64):
            for B in (1, 4, 8, 16):
                rng = np.random.default_rng(hash((B, n_bt, quantized))
                                            % 2 ** 31)
                args = _paged_case(rng, B, n_bt, quantized)
                t_ref = _time(pa.paged_attention_ref, *args)
                t_gat = _time(pa.paged_attention_gather, *args)
                o_ref = np.asarray(pa.paged_attention_ref(*args), np.float32)
                o_gat = np.asarray(pa.paged_attention_gather(*args),
                                   np.float32)
                max_err = float(np.abs(o_ref - o_gat).max())
                # greedy-proxy token identity: per slot, the argmax over the
                # flattened head output must agree between the engine's
                # routed oracle and the pre-kernel gather math
                tok_ok = bool((o_ref.reshape(B, -1).argmax(-1)
                               == o_gat.reshape(B, -1).argmax(-1)).all())
                kerr = None
                if kernel_check and B * n_bt <= 64:     # bounded interpret
                    o_ker = np.asarray(pa.paged_attention_pallas(
                        *args, interpret=True), np.float32)
                    kerr = float(np.abs(o_ker - o_ref).max())
                elim = pa.gathered_bytes(B, n_bt, bs, hkv, hd,
                                         quantized=quantized)
                stream = pa.streamed_bytes(B * n_bt, bs, hkv, hd,
                                           quantized=quantized)
                name = f"paged_decode_{pool}_B{B}_nbt{n_bt}"
                print(f"  {pool} B={B:<3d} n_bt={n_bt:<3d} "
                      f"oracle {t_ref:7.0f} us  gather {t_gat:7.0f} us  "
                      f"eliminated {elim / 1e3:8.1f} KB  "
                      f"streamed {stream / 1e3:8.1f} KB"
                      + (f"  kernel_err {kerr:.3g}" if kerr is not None
                         else ""))
                rows.append((name, t_ref,
                             f"gathered_bytes_eliminated={elim};"
                             f"streamed_bytes={stream};"
                             f"token_identical={tok_ok}"))
                records.append({
                    "name": name, "B": B, "n_bt": n_bt, "pool": pool,
                    "block_size": bs, "n_kv": hkv, "head_dim": hd,
                    "t_oracle_us": round(t_ref, 1),
                    "t_gather_us": round(t_gat, 1),
                    "gathered_bytes_eliminated": elim,
                    "streamed_bytes": stream,
                    "oracle_gather_max_err": max_err,
                    "token_identical": tok_ok,
                    "kernel_vs_oracle_max_err": kerr,
                })
    return rows, records


def run():
    rows = []
    M, K, N = 256, 2048, 2048
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(M, K)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(K, N)).astype(np.float32))
    q8 = psi.quantize_weights(w, 8, axis=0)
    q5 = psi.quantize_weights(w, 5, axis=0)
    planes = psi.pack_int5(q5.codes)

    f_bf16 = jax.jit(lambda x, w: x @ w)
    f_int8 = jax.jit(lambda x, c, s: ref.psi_matmul_int8_ref(x, c, s))
    f_int5 = jax.jit(lambda x, p, s: ref.psi_matmul_int5_ref(x, p, s))

    t_b = _time(f_bf16, x, w)
    t_8 = _time(f_int8, x, q8.codes, q8.scale.reshape(-1))
    t_5 = _time(f_int5, x, planes, q5.scale.reshape(-1))
    wb = K * N
    print(f"psi_matmul {M}x{K}x{N} (CPU oracle timings; bytes = HBM model):")
    print(f"  bf16      {t_b:9.0f} us   weight bytes {2.0 * wb / 1e6:7.2f} MB")
    print(f"  psi-int8  {t_8:9.0f} us   weight bytes {1.0 * wb / 1e6:7.2f} MB (2.0x less)")
    print(f"  psi-int5  {t_5:9.0f} us   weight bytes {0.625 * wb / 1e6:7.2f} MB (3.2x less)")
    rows.append(("kernel_bf16", t_b, f"bytes={2.0*wb:.0f}"))
    rows.append(("kernel_psi8", t_8, f"bytes={1.0*wb:.0f}"))
    rows.append(("kernel_psi5", t_5, f"bytes={0.625*wb:.0f}"))

    # Decode-shaped sweep: M = active decode slots.  Wall time is the CPU
    # oracle; the dispatch-relevant column is padded MACs — what the TPU
    # kernel grid actually issues with the old fixed bm=128 tile vs the
    # small-M tile ops.psi_matmul_2d now picks (>=2x fewer at M<=16 is the
    # acceptance bar; at M=1/f32 it is 16x).
    print(f"decode-shaped dispatch (K={K}, N={N}; M = active slots):")
    for M in (1, 4, 8, 16):
        xm = jnp.asarray(rng.normal(size=(M, K)).astype(np.float32))
        t_m = _time(f_int8, xm, q8.codes, q8.scale.reshape(-1))
        bm = pk.pick_bm(M, jnp.float32)
        macs_old = pk.padded_macs(M, K, N)            # fixed 128-row tile
        macs_new = pk.padded_macs(M, K, N, bm=bm)
        ratio = macs_old / macs_new
        print(f"  M={M:<3d} bm {pk.DEFAULT_BM}->{bm:<3d} "
              f"padded MACs {macs_old / 1e6:7.1f}M -> {macs_new / 1e6:6.1f}M "
              f"({ratio:4.1f}x fewer)  oracle {t_m:7.0f} us")
        rows.append((f"kernel_decode_m{M}", t_m,
                     f"bm={bm};padded_macs={macs_new};"
                     f"macs_vs_128tile={ratio:.1f}x"))

    # paged-decode read-side sweep (no interpret-mode kernel check here to
    # keep `python -m benchmarks.run` fast; the CI kernel-bench leg runs
    # `-m benchmarks.kernel_bench --kernel-check --out BENCH_kernel.json`)
    prows, _ = paged_sweep(kernel_check=False)
    rows.extend(prows)
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=None,
                    help="write the paged-decode sweep as machine-readable "
                         "JSON (BENCH_kernel.json)")
    ap.add_argument("--kernel-check", action="store_true",
                    help="also run the interpret-mode Pallas kernel against "
                         "the oracle on the bounded-size configs")
    args = ap.parse_args(argv)
    if args.out is None:
        run()
        return
    _, records = paged_sweep(kernel_check=args.kernel_check)
    with open(args.out, "w") as f:
        json.dump({"rows": records}, f, indent=1)
    print(f"wrote {args.out}: {len(records)} paged-decode configs")


if __name__ == "__main__":
    main()
