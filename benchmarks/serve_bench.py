"""Serving benchmark: static-batch vs continuous-batch on one arrival trace.

Replays the same Poisson arrival trace (heterogeneous per-request decode
budgets) through the slot-based engine twice — once with admission barriered
until the whole batch drains (classic static batching), once with
iteration-level admission into free slots (continuous batching, DESIGN.md §3)
— and reports tokens/s plus p50/p99 request latency for each.  Both runs use
the identical jitted prefill/decode functions, so the delta isolates the
scheduling policy: static batching pays (a) the convoy effect — admission
waits for the slowest sequence in the batch — and (b) dead decode slots
between a sequence's retirement and the batch barrier.

  PYTHONPATH=src python -m benchmarks.serve_bench --arch qwen3-8b --reduced \\
      --quant psi8

Sharded serving (mesh-native Executor, DESIGN.md §5) runs the same bench
with decode slots partitioned over the data axis — token-identical results:

  XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \\
      python -m benchmarks.serve_bench --arch qwen3-8b --reduced \\
      --quant psi8 --mesh 4x2
"""
from __future__ import annotations

import argparse
import time

from repro.launch.serve import add_serve_args, build_server, trace_from_args


def _fmt(stats):
    return (f"{stats['tok_per_s']:8.1f} tok/s | "
            f"latency p50 {stats['p50_latency_s'] * 1e3:7.1f}ms "
            f"p99 {stats['p99_latency_s'] * 1e3:7.1f}ms | "
            f"ttft p50 {stats['p50_ttft_s'] * 1e3:6.1f}ms | "
            f"{stats['decode_steps']} steps")


def run_bench(args):
    server, cfg = build_server(args)

    def trace():
        return trace_from_args(args, cfg)

    # Warm up every shape once up front; per-mode serve() then skips warmup so
    # both modes run against the same compiled functions.
    server.warmup(trace())
    done_s, stat_s = server.serve(trace(), continuous=False, warmup=False)
    done_c, stat_c = server.serve(trace(), continuous=True, warmup=False)

    # Greedy decode on the same trace must generate identical tokens — the
    # scheduling policy may only change *when* work runs, never the results.
    for rs, rc in zip(sorted(done_s, key=lambda r: r.rid),
                      sorted(done_c, key=lambda r: r.rid)):
        assert rs.tokens == rc.tokens, f"req {rs.rid} diverged across modes"

    speedup = stat_c["tok_per_s"] / stat_s["tok_per_s"]
    p99_ratio = stat_c["p99_latency_s"] / stat_s["p99_latency_s"]
    mesh = server.executor.mesh
    print(f"  mesh      : {dict(mesh.shape)} "
          f"({stat_c['slot_shards']} slot shard(s) over the data axis)")
    print(f"  static    : {_fmt(stat_s)}")
    print(f"  continuous: {_fmt(stat_c)}")
    print(f"  continuous/static: {speedup:.2f}x tokens/s, "
          f"{p99_ratio:.2f}x p99 latency "
          f"({stat_c['n_requests']} reqs, {stat_c['tokens']} tokens, "
          f"decode compiles: {stat_c['decode_compiles']})")
    return stat_s, stat_c, speedup, p99_ratio


def run():
    """Entry point for the benchmarks.run harness (reduced CPU defaults)."""
    ap = argparse.ArgumentParser()
    add_serve_args(ap)
    args = ap.parse_args(["--arch", "qwen3-8b", "--reduced", "--quant",
                          "psi8"])
    t0 = time.time()
    _, stat_c, speedup, p99_ratio = run_bench(args)
    us = (time.time() - t0) * 1e6
    return [("serve_bench", us,
             f"cont_vs_static={speedup:.2f}x;p99_ratio={p99_ratio:.2f};"
             f"tok_per_s={stat_c['tok_per_s']:.0f}")]


def main():
    ap = argparse.ArgumentParser()
    add_serve_args(ap)
    args = ap.parse_args()
    run_bench(args)


if __name__ == "__main__":
    main()
