"""Serving benchmark: scheduling policy AND cache layout on one trace.

Four sections, all asserting greedy outputs are token-identical —
scheduling, cache layout, and prefix reuse may only change *when and
where* work runs, never the results:

1. **static vs continuous** (DESIGN.md §3): admission barriered until the
   whole batch drains vs iteration-level admission into free slots.  The
   delta isolates the scheduling policy: static pays the convoy effect and
   dead slots between retirement and the batch barrier.
2. **dense vs paged layout** at equal geometry: same ``max_batch`` /
   ``max_seq``, reporting the cache-memory columns (dense slab bytes vs
   paged pool bytes at equal capacity, peak block utilization %).
3. **capacity at equal cache bytes**: a dense server provisions
   ``max_batch`` worst-case slots; a paged server with the SAME usable
   cache bytes (``n_blocks * block_size == max_batch * max_seq``) but twice
   the slots admits strictly more concurrent requests, because blocks are
   reserved per request (bucketed prompt + its own ``max_new``) instead of
   per worst-case slot.
4. **shared-system-prompt trace** (DESIGN.md §3 "Prefix cache"): every
   request carries the same 256-token prefix + an 8-token unique tail;
   ``--prefix-cache on`` serves the prefix out of ref-counted pool blocks
   and prefills only the tail.  Reports prefix hit rate, prefilled vs
   reused tokens, and p50 TTFT with/without the cache, and asserts the
   cached run is token-identical with a measured hit rate > 0, strictly
   fewer mean prefilled tokens, and a p50 TTFT win.
5. **self-speculative decoding** (DESIGN.md §"Self-speculative decoding"):
   the same trace served plain vs with ``--speculative 3:4`` — a psi3
   draft view of the SAME checkpoint drafting 4 tokens/round, verified in
   one target-width pass.  Both runs use a QAT-preconditioned checkpoint
   (``--qat-precondition 3``: random-init logit margins drown in 3-bit
   noise; a trained checkpoint's margins are what speculation exploits).
   Asserts token identity, the compile-exactly-twice contract, and a mean
   accepted length > 1; reports the tokens/s ratio and draft overhead.
6. **SLO scheduling on a bursty heavy-tail trace** (DESIGN.md §3 "SLO
   scheduling"): requests arrive in bursts with a heavy tail of
   long-prompt/long-budget requests, served FIFO + worst-case reservation
   vs ``--slo default`` + ``--prefill-chunk`` on a deliberately tight
   block pool.  Asserts token identity (priority ordering, chunked
   prefill, and preemption/restore may reorder work, never change it),
   decode-compiles-exactly-once, preemptions actually observed, and a
   strict interactive-class p99 TTFT win for the SLO engine — the class
   the policy protects; the overall tail is allowed to tie since batch
   requests absorb the delay by design.

Results go to stdout AND to a machine-readable ``BENCH_serve.json`` (like
``BENCH_quant.json``) so CI can track the serving trajectory across PRs;
the file is re-read through a STRICT ``json.loads`` (non-finite constants
rejected) so an ``Infinity`` regression can never ship a broken artifact.

  PYTHONPATH=src python -m benchmarks.serve_bench --arch qwen3-8b --reduced \\
      --quant psi8 [--out BENCH_serve.json]

Sharded serving (mesh-native Executor, DESIGN.md §5) runs the same bench
with decode slots and cache blocks partitioned over the data axis —
token-identical results:

  XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \\
      python -m benchmarks.serve_bench --arch qwen3-8b --reduced \\
      --quant psi8 --mesh 4x2
"""
from __future__ import annotations

import argparse
import gc
import json
import time

import jax

from repro.core.quantizer import parse_quant_mode
from repro.launch.serve import add_serve_args, build_server, trace_from_args
from repro.launch.slo import bursty_heavy_tail_trace, parse_slo_spec

DEFAULT_OUT = "BENCH_serve.json"


def _fmt(stats):
    return (f"{stats['tok_per_s']:8.1f} tok/s | "
            f"latency p50 {stats['p50_latency_s'] * 1e3:7.1f}ms "
            f"p99 {stats['p99_latency_s'] * 1e3:7.1f}ms | "
            f"ttft p50 {stats['p50_ttft_s'] * 1e3:6.1f}ms | "
            f"{stats['decode_steps']} steps | peak "
            f"{stats['peak_concurrency']} live")


def _tokens_by_rid(done):
    return {r.rid: tuple(r.tokens) for r in done}


def _assert_identical(a, b, what):
    ta, tb = _tokens_by_rid(a), _tokens_by_rid(b)
    assert ta == tb, f"token divergence across {what}"


def _clone_args(args, **over):
    ns = argparse.Namespace(**vars(args))
    for k, v in over.items():
        setattr(ns, k, v)
    return ns


def _strict_load(path):
    """Round-trip the emitted artifact through a STRICT parser: json.loads
    accepts bare Infinity/NaN by default, so a non-finite stat (the old
    ``tok_per_s: inf`` bug) would silently ship an artifact that breaks
    strict consumers.  Raise instead."""
    def reject(const):
        raise ValueError(f"non-finite JSON constant {const!r} in {path}")
    with open(path) as f:
        return json.load(f, parse_constant=reject)


def run_bench(args, out_path=None):
    server, cfg = build_server(args)

    def trace(a=args):
        return trace_from_args(a, cfg)

    # ---- 1. scheduling policy (on the configured/default layout) ----
    server.warmup(trace())
    done_s, stat_s = server.serve(trace(), continuous=False, warmup=False)
    done_c, stat_c = server.serve(trace(), continuous=True, warmup=False)
    _assert_identical(done_s, done_c, "static/continuous")

    speedup = stat_c["tok_per_s"] / stat_s["tok_per_s"]
    p99_ratio = stat_c["p99_latency_s"] / stat_s["p99_latency_s"]
    mesh = server.executor.mesh
    print(f"  mesh      : {dict(mesh.shape)} "
          f"({stat_c['slot_shards']} slot shard(s) over the data axis)")
    print(f"  layout    : {stat_c['cache_layout']} "
          f"({stat_c['cache_bytes'] / 1e6:.2f} MB cache)")
    print(f"  static    : {_fmt(stat_s)}")
    print(f"  continuous: {_fmt(stat_c)}")
    print(f"  continuous/static: {speedup:.2f}x tokens/s, "
          f"{p99_ratio:.2f}x p99 latency "
          f"({stat_c['n_requests']} reqs, {stat_c['tokens']} tokens, "
          f"decode compiles: {stat_c['decode_compiles']})")

    payload = {
        "bench": "serve_bench", "arch": args.arch, "reduced": args.reduced,
        "quant": args.quant, "mesh": dict(mesh.shape),
        "requests": args.requests, "max_batch": args.max_batch,
        "modes": {"static": stat_s, "continuous": stat_c},
        "cont_vs_static_tok_per_s": round(speedup, 3),
        "cont_vs_static_p99": round(p99_ratio, 3),
    }

    capacity_win = None
    if server.paged:
        # ---- 2. layout equivalence + cache-memory columns ----
        dense_server, _ = build_server(_clone_args(args,
                                                   cache_layout="dense",
                                                   cache_blocks=None,
                                                   prefix_cache="off"))
        done_d, stat_d = dense_server.serve(trace(), continuous=True)
        _assert_identical(done_c, done_d, "paged/dense layouts")
        dense_b, paged_b = stat_d["cache_bytes"], stat_c["cache_bytes"]
        print(f"  cache mem : dense {dense_b / 1e6:.2f} MB vs paged "
              f"{paged_b / 1e6:.2f} MB at equal capacity "
              f"({stat_c['n_blocks']}x{stat_c['block_size']} blocks "
              f"+ {args.max_batch} scratch, peak block util "
              f"{stat_c['block_util_pct']}%)")
        payload["layout_equivalence"] = {
            "token_identical": True,
            "dense_cache_bytes": dense_b,
            "paged_cache_bytes": paged_b,
            "paged_block_util_pct": stat_c["block_util_pct"],
            "dense": stat_d,
        }

        # ---- 3. capacity at an equal cache-byte budget ----
        # Same usable KV bytes as the dense slab (n_blocks * block_size ==
        # max_batch * max_seq), twice the decode slots: heterogeneous
        # requests reserve only their own need, so strictly more of them
        # fit concurrently.  A heterogeneous trace (prompt jitter + wide
        # decode budgets) is what a dense worst-case slab over-provisions.
        cap_args = _clone_args(
            args, max_batch=2 * args.max_batch,
            prompt_jitter=max(args.prompt_jitter, 8), min_new=1,
            prefix_cache="off")     # isolate the layout from prefix reuse
        cap_dense, _ = build_server(_clone_args(cap_args,
                                                cache_layout="dense",
                                                cache_blocks=None,
                                                max_batch=args.max_batch))
        # budget derived from the CAPACITY dense baseline's own geometry
        # (its max_seq can exceed the section-1 server's when the jitter
        # bump widens the prompt bucket): usable paged rows == dense rows.
        bsz = cap_dense.cfg.cache_block_size
        budget_blocks = args.max_batch * (cap_dense.max_seq // bsz)
        cap_paged, _ = build_server(_clone_args(
            cap_args, cache_blocks=budget_blocks))
        assert cap_paged.max_seq == cap_dense.max_seq
        dtrace = trace(cap_args)
        ptrace = trace(cap_args)
        done_cd, stat_cd = cap_dense.serve(dtrace, continuous=True)
        done_cp, stat_cp = cap_paged.serve(ptrace, continuous=True)
        _assert_identical(done_cd, done_cp, "capacity dense/paged")
        capacity_win = (stat_cp["peak_concurrency"],
                        stat_cd["peak_concurrency"])
        print(f"  capacity  : equal budget "
              f"{stat_cd['cache_bytes'] / 1e6:.2f} MB dense KV -> paged "
              f"admits {stat_cp['peak_concurrency']} concurrent vs dense "
              f"{stat_cd['peak_concurrency']} "
              f"({stat_cp['tok_per_s'] / stat_cd['tok_per_s']:.2f}x "
              f"tokens/s)")
        assert stat_cp["peak_concurrency"] > stat_cd["peak_concurrency"], (
            "paged layout must admit strictly more concurrent requests "
            "than dense at the same cache-byte budget")
        payload["capacity"] = {
            "cache_byte_budget_dense": stat_cd["cache_bytes"],
            "paged_usable_blocks": cap_paged.executor.n_blocks,
            "dense_slots": args.max_batch,
            "paged_slots": 2 * args.max_batch,
            "dense": stat_cd,
            "paged": stat_cp,
            "dense_peak_concurrency": stat_cd["peak_concurrency"],
            "paged_peak_concurrency": stat_cp["peak_concurrency"],
        }

    if server.paged and cfg.rope == "rope":
        # ---- 4. shared-system-prompt trace: prefix cache off vs on ----
        # (skipped for non-plain-RoPE paged archs — qwen2-vl's mrope
        # positions cannot be replayed from a scalar pos0)
        # A dedicated trace (one 256-token system prompt + 8-token unique
        # tails by default — override with --shared-prefix-len /
        # --prompt-len) replayed through two fresh servers; both warm up
        # first so TTFT measures prefill work, not XLA.
        # Default shape: a LONG shared prefix (256 tokens) with short fixed
        # decode budgets keeps TTFT dominated by the prefill compute the
        # cache elides — on the reduced CPU model, shorter prefixes leave
        # the delta inside dispatch noise.  A user-supplied
        # --shared-prefix-len keeps the user's own trace shape.  TTFT is
        # the MEDIAN over 3 serves per config (tokens are deterministic;
        # wall time on a shared CI box is not).
        user_set = bool(getattr(args, "shared_prefix_len", 0))
        pargs = _clone_args(
            args,
            shared_prefix_len=(args.shared_prefix_len if user_set else 256),
            prompt_len=(args.prompt_len if user_set else 8),
            requests=(args.requests if user_set else 16),
            max_new=(args.max_new if user_set else 6),
            min_new=(args.min_new if user_set else 6),
            prompt_jitter=0, cache_blocks=None, prefix_cache="off")
        off_server, pcfg = build_server(pargs)
        on_server, _ = build_server(_clone_args(pargs, prefix_cache="on"))

        def ptrace():
            return trace_from_args(pargs, pcfg)

        def median_serve(server):
            server.warmup(ptrace())
            runs = [server.serve(ptrace(), continuous=True, warmup=False)
                    for _ in range(3)]
            runs.sort(key=lambda ds: ds[1]["p50_ttft_s"])
            return runs[1]                         # median-TTFT run

        done_off, stat_off = median_serve(off_server)
        done_on, stat_on = median_serve(on_server)
        _assert_identical(done_off, done_on, "prefix cache off/on")
        pc = stat_on["prefix_cache"]
        assert stat_on["decode_compiles"] == 1
        if not user_set:
            # hard wins are asserted only on the curated default shape —
            # a user-chosen prefix (e.g. shorter than one aligned block)
            # can legitimately miss the cache or sit inside CPU dispatch
            # noise, and should produce a report, not an AssertionError
            assert pc["hit_rate"] > 0, \
                "shared-prefix trace must hit the cache"
            assert (stat_on["prefilled_tokens_mean"]
                    < stat_off["prefilled_tokens_mean"]), \
                "prefix cache must lower mean prefilled tokens per request"
            assert stat_on["p50_ttft_s"] < stat_off["p50_ttft_s"], \
                "prefix cache must win p50 TTFT on the shared-prefix trace"
        ttft_win = (stat_off["p50_ttft_s"] / stat_on["p50_ttft_s"]
                    if stat_on["p50_ttft_s"] > 0 else 0.0)
        print(f"  prefix    : shared {pargs.shared_prefix_len}-token prompt "
              f"-> hit rate {pc['hit_rate']:.2f}, "
              f"{stat_on['prefix_tokens_reused']} tok reused, prefilled "
              f"mean {stat_on['prefilled_tokens_mean']:.1f} vs "
              f"{stat_off['prefilled_tokens_mean']:.1f} | p50 ttft "
              f"{stat_on['p50_ttft_s'] * 1e3:.1f}ms vs "
              f"{stat_off['p50_ttft_s'] * 1e3:.1f}ms ({ttft_win:.2f}x)")
        payload["prefix_cache"] = {
            "shared_prefix_len": pargs.shared_prefix_len,
            "token_identical": True,
            "hit_rate": pc["hit_rate"],
            "tokens_reused": stat_on["prefix_tokens_reused"],
            "prefilled_tokens_mean_on": stat_on["prefilled_tokens_mean"],
            "prefilled_tokens_mean_off": stat_off["prefilled_tokens_mean"],
            "p50_ttft_s_on": stat_on["p50_ttft_s"],
            "p50_ttft_s_off": stat_off["p50_ttft_s"],
            "ttft_win": round(ttft_win, 3),
            "off": stat_off,
            "on": stat_on,
        }

    kind, sbits = ((None, None) if args.quant == "none"
                   else parse_quant_mode(args.quant))
    if server.paged and cfg.rope == "rope" and kind == "psi" and sbits > 3:
        # ---- 5. self-speculative decoding: psi3 draft + k=4 verify ----
        # Both servers serve the QAT-preconditioned checkpoint so the
        # spec-off baseline emits the same tokens; only the decode engine
        # differs.  The curated default shape (user overrides keep their
        # own) uses longer fixed-ish budgets so rounds dominate prefill.
        # Curated default shape: fixed full-length decode budgets keep the
        # comparison decode-dominated (where the draft/verify round pays),
        # and the tokens/s is the MEDIAN over 3 serves per engine — the
        # tokens are deterministic, wall time on a shared CI box is not.
        user_set = bool(getattr(args, "speculative", None))
        sargs = _clone_args(
            args,
            speculative=(args.speculative if user_set else "3:4"),
            qat_precondition=(getattr(args, "qat_precondition", 0) or 3),
            requests=(args.requests if user_set else 12),
            max_batch=(args.max_batch if user_set else 2),
            max_new=(args.max_new if user_set else 64),
            min_new=(args.min_new if user_set else 64),
            prompt_jitter=0, cache_blocks=None, prefix_cache="off")
        spec_off, scfg = build_server(_clone_args(sargs, speculative=None))
        spec_on, _ = build_server(sargs)

        def strace():
            return trace_from_args(sargs, scfg)

        def median_spec_serve(server):
            # Collect before each timed serve: earlier sections leave dead
            # servers in reference cycles (Executor <-> jitted bound
            # methods), and the cyclic GC otherwise fires MID-SERVE —
            # releasing their XLA buffers inside the timed loop skewed the
            # first post-section serve ~4x.
            server.warmup(strace())
            runs = []
            for _ in range(3):
                gc.collect()
                runs.append(server.serve(strace(), continuous=True,
                                         warmup=False))
            runs.sort(key=lambda ds: ds[1]["tok_per_s"])
            return runs[1]                       # median-throughput run

        done_soff, stat_soff = median_spec_serve(spec_off)
        done_son, stat_son = median_spec_serve(spec_on)
        _assert_identical(done_soff, done_son, "speculative off/on")
        sp = stat_son["speculative"]
        spec_ratio = (stat_son["tok_per_s"] / stat_soff["tok_per_s"]
                      if stat_soff["tok_per_s"] > 0 else 0.0)
        print(f"  spec      : psi{sp['draft_bits']} draft, k={sp['k']} -> "
              f"accepted {stat_son['accepted_per_step']:.2f}/round over "
              f"{sp['rounds']} rounds | {stat_son['tok_per_s']:.1f} vs "
              f"{stat_soff['tok_per_s']:.1f} tok/s ({spec_ratio:.2f}x) | "
              f"draft overhead {stat_son['draft_overhead_s']:.3f}s | "
              f"compiles {sp['spec_compiles']}")
        assert sp["spec_compiles"] == {"draft": 1, "verify": 1,
                                       "decode": 0}, (
            f"speculative compile contract: {sp['spec_compiles']}")
        assert sp["mean_accepted"] > 1, (
            f"speculative draft must amortize the verify pass: mean "
            f"accepted length {sp['mean_accepted']} <= 1")
        if not user_set:
            # hard wall-clock win only on the curated shape (measured
            # ~1.5x on the reduced CPU config; generous flake margin)
            assert spec_ratio > 1.1, (
                f"speculative decode must beat plain decode on the "
                f"curated trace, got {spec_ratio:.2f}x")
        payload["speculative"] = {
            "draft_bits": sp["draft_bits"], "k": sp["k"],
            "token_identical": True,
            "rounds": sp["rounds"],
            "mean_accepted": sp["mean_accepted"],
            "accepted_per_step": stat_son["accepted_per_step"],
            "draft_overhead_s": stat_son["draft_overhead_s"],
            "tok_per_s_off": stat_soff["tok_per_s"],
            "tok_per_s_on": stat_son["tok_per_s"],
            "speedup": round(spec_ratio, 3),
            "spec_compiles": sp["spec_compiles"],
            "off": stat_soff,
            "on": stat_son,
        }

    if server.paged and cfg.rope == "rope":
        # ---- 6. SLO scheduling on a bursty heavy-tail trace ----
        # Curated shape: bursts of 8 back-to-back arrivals, half carrying a
        # long prompt AND a long decode budget, over a block pool sized so
        # a burst of longs cannot all fit — the traffic FIFO + worst-case
        # reservation head-of-line-blocks on.  The SLO engine admits
        # optimistically (reserve_frac of the decode budget), chunks the
        # long prefills between decode steps, and preempts the youngest
        # batch-class runner under pool pressure (restore = suffix-only
        # re-prefill out of the published blocks).  The asserted metric is
        # INTERACTIVE-class p99 TTFT — the class the policy exists to
        # protect; overall p99 may tie because batch requests absorb the
        # delay by design.  Each engine's number is the MEDIAN over 3
        # serves — tokens are deterministic, wall time on a shared CI box
        # is not; the first serve also absorbs the lazy restore-shape
        # compiles (runtime-state-dependent, unforeseeable at warmup) so
        # the median measures scheduling, not XLA.
        slo_spec = "default@aging=5@reserve=0.1"
        base = _clone_args(
            args, requests=24, max_batch=4, prompt_len=56, max_new=32,
            min_new=32, prompt_jitter=0, cache_blocks=9,
            prefix_cache="off", speculative=None, qat_precondition=0,
            prefill_chunk=0, slo="off")
        slo_args = _clone_args(base, prefill_chunk=16, slo=slo_spec)
        fifo_server, bcfg = build_server(base)
        slo_server, _ = build_server(slo_args)
        policy = parse_slo_spec(slo_spec)

        def btrace():
            return bursty_heavy_tail_trace(
                base.requests, vocab_size=bcfg.vocab_size, seed=args.seed,
                burst_size=8, burst_gap_s=0.25, long_frac=0.5,
                long_prompt=56, short_prompt=8, long_new=32, short_new=8,
                mix=policy.mix([3.0, 2.0, 1.0]))

        def class_p99_ttft(done, priority=0):
            ts = sorted(r.ttft_s for r in done if r.priority == priority)
            if not ts:
                return 0.0
            return ts[min(len(ts) - 1, int(0.99 * (len(ts) - 1) + 0.999))]

        def median_slo_serve(server):
            server.warmup(btrace())
            runs = []
            for _ in range(3):
                gc.collect()
                runs.append(server.serve(btrace(), continuous=True,
                                         warmup=False))
            runs.sort(key=lambda ds: class_p99_ttft(ds[0]))
            return runs[1]                 # median interactive-p99 run

        done_fifo, stat_fifo = median_slo_serve(fifo_server)
        done_slo, stat_slo = median_slo_serve(slo_server)
        int_fifo = class_p99_ttft(done_fifo)
        int_slo = class_p99_ttft(done_slo)
        _assert_identical(done_fifo, done_slo, "fifo/slo scheduling")
        assert stat_slo["decode_compiles"] == 1, (
            f"SLO+chunked serving must keep the decode step compiling "
            f"exactly once, got {stat_slo['decode_compiles']}")
        assert stat_slo["preemptions"] > 0, (
            "the tight-pool bursty trace must exercise preemption")
        assert stat_slo["blocks_free_end"] == slo_server.executor.n_blocks, (
            "preemption/restore must leak no blocks")
        assert int_slo < int_fifo, (
            f"SLO scheduling must win interactive-class p99 TTFT on the "
            f"bursty heavy-tail trace: {int_slo:.3f}s vs FIFO "
            f"{int_fifo:.3f}s")
        slo_win = int_fifo / int_slo if int_slo > 0 else 0.0
        rc = stat_slo["prefix_cache"]
        print(f"  slo       : bursty tail -> interactive p99 ttft "
              f"{int_slo * 1e3:.1f}ms vs FIFO {int_fifo * 1e3:.1f}ms "
              f"({slo_win:.2f}x) | overall p99 "
              f"{stat_slo['p99_ttft_s'] * 1e3:.1f}ms vs "
              f"{stat_fifo['p99_ttft_s'] * 1e3:.1f}ms | "
              f"{stat_slo['preemptions']} preemptions, "
              f"{rc['restores']} restores "
              f"({rc['restored_tokens']} tok), "
              f"{stat_slo['prefill_chunks']} chunk pieces")
        payload["slo"] = {
            "token_identical": True,
            "trace": {"requests": base.requests, "burst_size": 8,
                      "long_frac": 0.5, "n_blocks": 9},
            "interactive_p99_ttft_s_fifo": int_fifo,
            "interactive_p99_ttft_s_slo": int_slo,
            "p99_ttft_s_fifo": stat_fifo["p99_ttft_s"],
            "p99_ttft_s_slo": stat_slo["p99_ttft_s"],
            "p99_ttft_win": round(slo_win, 3),
            "preemptions": stat_slo["preemptions"],
            "restores": rc["restores"],
            "restored_tokens": rc["restored_tokens"],
            "prefill_chunks": stat_slo["prefill_chunks"],
            "decode_compiles": stat_slo["decode_compiles"],
            "classes": stat_slo["slo"]["classes"],
            "fifo": stat_fifo,
            "slo": stat_slo,
        }

    # ---- 7. multi-step decode: horizon 1 vs 8 replay ----
    # Curated shape: 16 requests with fixed 48-token decode budgets over 4
    # slots keep the serve decode-round-dominated — exactly where the
    # per-token host round trip pays.  Horizon 8 drains the SAME trace with
    # one host sync per 8-step round (plus admissions), so syncs/token must
    # drop >= 4x and wall-clock tokens/s must strictly improve, with the
    # scan compiling once.  Tokens are deterministic; tokens/s is the
    # MEDIAN over 3 serves per engine (shared-CI wall time is not).
    user_h = int(getattr(args, "decode_horizon", 1) or 1)
    h_hi = user_h if user_h > 1 else 8
    margs = _clone_args(
        args, requests=16, max_batch=4, max_new=48, min_new=48,
        prompt_jitter=0, cache_blocks=None, prefix_cache="off",
        speculative=None, qat_precondition=0, prefill_chunk=0, slo="off")
    h1_server, mcfg = build_server(_clone_args(margs, decode_horizon=1))
    hM_server, _ = build_server(_clone_args(margs, decode_horizon=h_hi))

    def mtrace():
        return trace_from_args(margs, mcfg)

    def median_multi_serve(server):
        server.warmup(mtrace())
        runs = []
        for _ in range(3):
            gc.collect()
            runs.append(server.serve(mtrace(), continuous=True,
                                     warmup=False))
        runs.sort(key=lambda ds: ds[1]["tok_per_s"])
        return runs[1]                       # median-throughput run

    done_h1, stat_h1 = median_multi_serve(h1_server)
    done_hM, stat_hM = median_multi_serve(hM_server)
    _assert_identical(done_h1, done_hM, f"decode horizon 1/{h_hi}")
    for st in (stat_h1, stat_hM):            # serving-metrics contract
        for key in ("host_syncs", "host_syncs_per_token", "mfu",
                    "tokens_per_joule", "macs_per_token"):
            assert key in st, f"stats missing {key!r}"
    sync_ratio = (stat_h1["host_syncs_per_token"]
                  / stat_hM["host_syncs_per_token"]
                  if stat_hM["host_syncs_per_token"] > 0 else 0.0)
    multi_ratio = (stat_hM["tok_per_s"] / stat_h1["tok_per_s"]
                   if stat_h1["tok_per_s"] > 0 else 0.0)
    assert stat_hM["decode_compiles"] == 1, (
        f"multi-step serving must compile the horizon scan exactly once, "
        f"got {stat_hM['decode_compiles']}")
    assert sync_ratio >= 4, (
        f"horizon {h_hi} must cut host syncs/token >= 4x vs horizon 1, "
        f"got {sync_ratio:.2f}x ({stat_h1['host_syncs_per_token']} -> "
        f"{stat_hM['host_syncs_per_token']})")
    assert multi_ratio > 1, (
        f"horizon {h_hi} must strictly improve tokens/s, got "
        f"{multi_ratio:.2f}x ({stat_h1['tok_per_s']:.1f} -> "
        f"{stat_hM['tok_per_s']:.1f})")
    mesh_identity = "skipped"
    if len(jax.devices()) >= 8:
        # (4, 2)-mesh twin: the sharded horizon engine emits the exact
        # single-device streams (one serve — identity, not timing).
        hmesh, _ = build_server(_clone_args(margs, mesh="4x2",
                                            decode_horizon=h_hi))
        done_hm, stat_hm = hmesh.serve(mtrace(), continuous=True)
        _assert_identical(done_h1, done_hm, f"horizon {h_hi} 1x1/(4,2)")
        assert stat_hm["decode_compiles"] == 1
        mesh_identity = True
    print(f"  multistep : horizon {h_hi} -> "
          f"{stat_hM['host_syncs_per_token']:.3f} vs "
          f"{stat_h1['host_syncs_per_token']:.3f} syncs/tok "
          f"({sync_ratio:.1f}x fewer) | {stat_hM['tok_per_s']:.1f} vs "
          f"{stat_h1['tok_per_s']:.1f} tok/s ({multi_ratio:.2f}x) | "
          f"mfu {stat_hM['mfu']:.2e} | "
          f"{stat_hM['tokens_per_joule']:.2f} tok/J | mesh "
          f"{mesh_identity}")
    payload["multistep"] = {
        "token_identical": True,
        "horizon": h_hi,
        "sync_ratio": round(sync_ratio, 3),
        "host_syncs_per_token_h1": stat_h1["host_syncs_per_token"],
        "host_syncs_per_token_hM": stat_hM["host_syncs_per_token"],
        "tok_per_s_h1": stat_h1["tok_per_s"],
        "tok_per_s_hM": stat_hM["tok_per_s"],
        "speedup": round(multi_ratio, 3),
        "decode_rounds": stat_hM.get("decode_rounds", 0),
        "decode_compiles": stat_hM["decode_compiles"],
        "mfu": stat_hM["mfu"],
        "tokens_per_joule": stat_hM["tokens_per_joule"],
        "mesh_identity": mesh_identity,
        "h1": stat_h1,
        "hM": stat_hM,
    }

    if out_path:
        with open(out_path, "w") as f:
            json.dump(payload, f, indent=2, allow_nan=False)
        _strict_load(out_path)         # fail loudly, never ship bad JSON
        print(f"  wrote {out_path}")
    return stat_s, stat_c, speedup, p99_ratio, capacity_win


def run():
    """Entry point for the benchmarks.run harness (reduced CPU defaults);
    emits the machine-readable BENCH_serve.json."""
    ap = argparse.ArgumentParser()
    add_serve_args(ap)
    args = ap.parse_args(["--arch", "qwen3-8b", "--reduced", "--quant",
                          "psi8"])
    t0 = time.time()
    _, stat_c, speedup, p99_ratio, cap = run_bench(args,
                                                   out_path=DEFAULT_OUT)
    us = (time.time() - t0) * 1e6
    derived = (f"cont_vs_static={speedup:.2f}x;p99_ratio={p99_ratio:.2f};"
               f"tok_per_s={stat_c['tok_per_s']:.0f};"
               f"layout={stat_c['cache_layout']}")
    if cap:
        derived += f";capacity_paged_vs_dense={cap[0]}v{cap[1]}"
    d = _strict_load(DEFAULT_OUT)
    if "prefix_cache" in d:
        pc = d["prefix_cache"]
        derived += (f";prefix_hit={pc['hit_rate']:.2f}"
                    f";prefix_ttft_win={pc['ttft_win']:.2f}x")
    if "speculative" in d:
        sp = d["speculative"]
        derived += (f";spec_speedup={sp['speedup']:.2f}x"
                    f";spec_accepted={sp['mean_accepted']:.2f}")
    if "slo" in d:
        sl = d["slo"]
        derived += (f";slo_p99_ttft_win={sl['p99_ttft_win']:.2f}x"
                    f";slo_preemptions={sl['preemptions']}")
    if "multistep" in d:
        ms = d["multistep"]
        derived += (f";horizon{ms['horizon']}_sync_ratio="
                    f"{ms['sync_ratio']:.2f}x"
                    f";horizon_speedup={ms['speedup']:.2f}x"
                    f";mfu={ms['mfu']:.2e}"
                    f";tok_per_joule={ms['tokens_per_joule']:.2f}")
    return [("serve_bench", us, derived)]


def main():
    ap = argparse.ArgumentParser()
    add_serve_args(ap)
    ap.add_argument("--out", default=None,
                    help=f"write machine-readable results (default off on "
                         f"the CLI; benchmarks.run writes {DEFAULT_OUT})")
    args = ap.parse_args()
    run_bench(args, out_path=args.out)


if __name__ == "__main__":
    main()
