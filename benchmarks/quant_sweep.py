"""Bits-sweep benchmark: the perf trajectory of the PsiFormat registry.

For each registered serving width (plus the unquantized baseline, which
stores f32 — 4 B/weight — and casts to the activation dtype at use) on the
reduced qwen3-8b config, measures:

* ``model_bytes`` — serving-format parameter footprint
  (``quantizer.quantized_bytes``: packed sub-byte planes + scales);
* ``padded_macs`` — MACs the decode-shaped kernel dispatch actually issues
  for one decode step's GEMMs (``psi_matmul.padded_macs`` with ``pick_bm``);
* ``tok_per_s`` — continuous-batching tokens/s through the slot engine on a
  short arrival trace.

Results go to stdout AND to a machine-readable ``BENCH_quant.json`` so CI
can track the bits -> bytes -> throughput curve across PRs.

  PYTHONPATH=src python -m benchmarks.quant_sweep [--out BENCH_quant.json]
"""
from __future__ import annotations

import argparse
import json
import time
from types import SimpleNamespace

DEFAULT_BITS = (4, 5, 8)          # sub-5-bit frontier + the paper's points
DEFAULT_OUT = "BENCH_quant.json"


def _serve_args(quant: str) -> SimpleNamespace:
    return SimpleNamespace(
        arch="qwen3-8b", reduced=True, quant=quant, quant_policy=None,
        requests=8, max_batch=4, arrival_rate=1000.0, max_new=16, min_new=4,
        prompt_len=16, prompt_jitter=0, eos_id=-1, seed=0, mesh=None)


def _decode_padded_macs(cfg, max_batch: int) -> int:
    """Padded MACs for one decode step's block GEMMs under the decode-shaped
    M-tile dispatch (DESIGN.md §2).  The M tile is picked with the config's
    activation dtype — exactly what ops.psi_matmul_2d does at run time
    (bf16's sublane floor is 16, f32's is 8)."""
    import jax.numpy as jnp
    from repro.kernels import psi_matmul as pk
    d, f = cfg.d_model, cfg.d_ff
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    bm = pk.pick_bm(max_batch, jnp.dtype(cfg.dtype))
    gemms = [(d, (hq + 2 * hkv) * hd), ((hq * hd), d),    # qkv + out proj
             (d, f), (d, f), (f, d)]                      # swiglu mlp
    per_layer = sum(pk.padded_macs(max_batch, K, N, bm=bm) for K, N in gemms)
    lm_head = pk.padded_macs(max_batch, d, cfg.vocab_size, bm=bm)
    return per_layer * cfg.n_layers + lm_head


def sweep(bits_list=DEFAULT_BITS, out_path=DEFAULT_OUT):
    import jax
    from repro.core import psi
    from repro.core.quantizer import quantized_bytes
    from repro.launch.serve import build_server, trace_from_args

    rows = []
    for quant in ("none",) + tuple(f"psi{b}" for b in bits_list):
        args = _serve_args(quant)
        server, cfg = build_server(args)
        params_bytes = quantized_bytes(server.executor.params)
        t0 = time.time()
        _, stats = server.serve(trace_from_args(args, cfg), continuous=True)
        row = {
            "quant": quant,
            "bits": None if quant == "none" else int(quant[3:]),
            # the unquantized baseline *stores* f32 (init dtype; weights cast
            # to the activation dtype at use), so its measured model_bytes
            # imply 4 B/w — keep the declared figure consistent with what
            # this row actually measures, not the bf16 HBM-traffic model
            "bytes_per_weight": (4.0 if quant == "none" else
                                 psi.get_format(quant).bytes_per_weight()),
            "worst_case_rel_error": (0.0 if quant == "none" else
                                     psi.get_format(quant).worst_case_rel_error),
            "model_bytes": int(params_bytes),
            "padded_macs_per_decode_step": _decode_padded_macs(
                cfg, args.max_batch),
            "tok_per_s": round(stats["tok_per_s"], 2),
            "tokens": stats["tokens"],
            "wall_s": round(time.time() - t0, 3),
        }
        rows.append(row)
        print(f"  {quant:5s}: {row['model_bytes']/1e6:7.2f} MB, "
              f"{row['bytes_per_weight']:.3f} B/w, "
              f"{row['tok_per_s']:8.1f} tok/s")
    payload = {"bench": "quant_sweep", "arch": "qwen3-8b", "reduced": True,
               "rows": rows}
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"  wrote {out_path}")
    return rows


def run():
    """Entry point for the benchmarks.run harness (reduced CPU defaults)."""
    t0 = time.time()
    rows = sweep()
    us = (time.time() - t0) * 1e6
    by_q = {r["quant"]: r for r in rows}
    base = by_q["none"]["model_bytes"]
    derived = ";".join(
        f"{r['quant']}={base / r['model_bytes']:.2f}x" for r in rows[1:])
    return [("quant_sweep", us, derived)]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--bits", default=",".join(map(str, DEFAULT_BITS)),
                    help="comma-separated registered widths to sweep")
    args = ap.parse_args()
    bits = tuple(int(b) for b in args.bits.split(",") if b)
    sweep(bits, args.out)


if __name__ == "__main__":
    main()
