"""Paper Fig. 8: per-layer AlexNet processing time (batch 4) — TMA INT5/INT8
vs Eyeriss and DSIP."""
from __future__ import annotations

import time

from repro.core import baselines as bl, tma_model as tm


def run():
    t0 = time.time()
    layers = tm.alexnet_layers()
    t5 = {r.name: r.time_s for r in tm.analyze_network(layers, 5, batch=4)}
    t8 = {r.name: r.time_s for r in tm.analyze_network(layers, 8, batch=4)}
    print("Fig. 8 — AlexNet per-layer time, batch=4 (ms):")
    print(f"  {'layer':6s} {'TMA5':>8s} {'TMA8':>8s} {'Eyeriss':>9s} "
          f"{'DSIP':>9s} {'spdup5/Ey':>10s}")
    key_ratios = {}
    for l in layers:
        ey = bl.EYERISS.layer_time_s(l, 4)
        ds = bl.DSIP.layer_time_s(l, 4)
        r = ey / t5[l.name]
        key_ratios[l.name] = r
        print(f"  {l.name:6s} {t5[l.name]*1e3:8.2f} {t8[l.name]*1e3:8.2f} "
              f"{ey*1e3:9.2f} {ds*1e3:9.2f} {r:10.1f}")
    print(f"  conv3 speedup vs Eyeriss: {key_ratios['conv3']:.1f}x "
          "(paper 24.6x); vs DSIP: "
          f"{bl.DSIP.layer_time_s(layers[2],4)/t5['conv3']:.1f}x (paper 41.7x)")
    us = (time.time() - t0) * 1e6
    return [("fig8_latency", us, f"conv3_vs_eyeriss={key_ratios['conv3']:.1f}x")]


if __name__ == "__main__":
    run()
