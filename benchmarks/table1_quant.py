"""Paper Table I: multiplication error + inference-accuracy degradation per
number of PSI partitions.

* Multiplication-error column: EXACT reproduction (exhaustive over the
  integer grid).
* Accuracy column: LeNet-5 trained on procedural MNIST-like digits (no
  network access in this container), evaluated FP32 vs PSI-INT5/INT8.
  AlexNet/ImageNet cannot be trained here; its column is reported from the
  per-layer weight-error propagation model and marked modeled.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import psi
from repro.data.pipeline import synthetic_mnist
from repro.models import cnn


def multiplication_error_rows():
    rows = []
    for bits, n_psi in ((5, 2), (8, 4)):
        w_min = -16 if bits == 5 else -128
        w = np.arange(w_min, -w_min)
        vals = np.asarray(psi.psi_value_table(bits))[:len(w)]
        rel = np.abs(vals - w) / np.maximum(np.abs(w), 1)
        rows.append({
            "partitions": f"{n_psi} PSIs",
            "weight_precision": f"INT{bits}",
            "worst_case_error_pct": 100 * float(rel.max()),
            "error_weights": sorted(int(x) for x in w[vals != w]),
        })
    return rows


def lenet_accuracy(steps: int = 220, seed: int = 0):
    """Train LeNet-5 FP32, then evaluate FP32 vs PSI-quantized weights."""
    import dataclasses
    cfg = cnn.LENET5
    params = cnn.init_cnn(cfg, jax.random.PRNGKey(seed))
    xs, ys = synthetic_mnist(4096, seed=1)
    xt, yt = synthetic_mnist(1024, seed=2)

    @jax.jit
    def step(p, batch):
        loss, grads = jax.value_and_grad(
            lambda pp: cnn.cnn_loss(pp, batch, cfg)[0])(p)
        return loss, jax.tree_util.tree_map(lambda a, g: a - 0.05 * g, p, grads)

    bs = 128
    for i in range(steps):
        lo = (i * bs) % (len(xs) - bs)
        batch = {"images": jnp.asarray(xs[lo:lo + bs]),
                 "labels": jnp.asarray(ys[lo:lo + bs])}
        _, params = step(params, batch)

    test = {"images": jnp.asarray(xt), "labels": jnp.asarray(yt)}
    _, m32 = cnn.cnn_loss(params, test, cfg)
    out = {"fp32_acc": float(m32["acc"])}
    for bits in (5, 8):
        qp = cnn.quantize_cnn(params, bits)
        qcfg = dataclasses.replace(cfg, quant_mode=f"psi{bits}")
        _, mq = cnn.cnn_loss(qp, test, qcfg)
        out[f"psi{bits}_acc"] = float(mq["acc"])
        out[f"psi{bits}_degradation_pct"] = 100 * (
            float(m32["acc"]) - float(mq["acc"]))
    return out


def run():
    t0 = time.time()
    rows = multiplication_error_rows()
    acc = lenet_accuracy()
    print("Table I — multiplication error (exact):")
    for r in rows:
        print(f"  {r['partitions']:7s} {r['weight_precision']:5s} "
              f"worst-case {r['worst_case_error_pct']:.1f}% at {r['error_weights']}")
    print("Table I — LeNet-5 (procedural MNIST):")
    print(f"  FP32 {acc['fp32_acc']:.3f}  "
          f"PSI-INT8 {acc['psi8_acc']:.3f} (d={acc['psi8_degradation_pct']:+.1f}pp)  "
          f"PSI-INT5 {acc['psi5_acc']:.3f} (d={acc['psi5_degradation_pct']:+.1f}pp)")
    us = (time.time() - t0) * 1e6
    derived = (f"int5_err={rows[0]['worst_case_error_pct']:.1f}%;"
               f"lenet_psi8_drop={acc['psi8_degradation_pct']:.2f}pp")
    return [("table1_quant", us, derived)]


if __name__ == "__main__":
    run()
