"""Paper Table II: performance of the implemented TMA accelerator
(2,304 MACs, 4 MB SRAM, 200 MHz, 576/288 GMACS peak, 62 fps AlexNet)."""
from __future__ import annotations

import time

from repro.core import tma_model as tm


def run():
    t0 = time.time()
    layers = tm.alexnet_layers()
    rows = {
        "n_macs": tm.MACS_PARALLEL,
        "sram_mb": tm.SRAM_BYTES / 2 ** 20,
        "clock_mhz": tm.FPGA_FREQ_HZ / 1e6,
        "fifo_bytes": tm.FIFO_BYTES,
        "peak_gmacs_int5": tm.peak_throughput_gmacs(5, 250e6),
        "peak_gmacs_int8": tm.peak_throughput_gmacs(8, 250e6),
        "gate_count": tm.gate_count_model()["total"],
        "alexnet_fps_int8": tm.frame_rate(layers, 8),
        "alexnet_fps_int5": tm.frame_rate(layers, 5),
        "paper_alexnet_fps": 62.0,
    }
    print("Table II — implemented TMA accelerator:")
    for k, v in rows.items():
        print(f"  {k:22s} {v:,.1f}" if isinstance(v, float) else
              f"  {k:22s} {v:,}")
    print("  note: modeled fps excludes DRAM/control overheads -> sits "
          f"{rows['alexnet_fps_int8'] / rows['paper_alexnet_fps']:.2f}x "
          "above the published 62 fps (INT8)")
    us = (time.time() - t0) * 1e6
    return [("table2_perf", us,
             f"fps_int8={rows['alexnet_fps_int8']:.1f};peak_int5="
             f"{rows['peak_gmacs_int5']:.0f}GMACS")]


if __name__ == "__main__":
    run()
