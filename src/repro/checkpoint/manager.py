"""Fault-tolerant checkpointing.

* **Atomic**: write to ``<dir>/tmp.<step>``, fsync, then ``os.rename`` — a
  crash mid-save never corrupts the latest checkpoint.
* **Async**: ``save(..., blocking=False)`` snapshots to host memory
  (device_get) and writes on a background thread, overlapping I/O with the
  next training steps; ``wait()`` joins before the next save or exit.
* **Keep-k** rotation, plus "keep every Nth" permanent snapshots.
* **Resumable data state**: the data-iterator state dict rides in the
  checkpoint, so restart resumes the exact sample stream.
* **Elastic reshard-on-load**: checkpoints store *global* (unsharded) arrays;
  ``restore(..., shardings=...)`` device_puts each leaf with the *current*
  mesh's NamedSharding — a job restarted on a different device count or mesh
  shape just reshards (DESIGN.md §5).

Format: one ``msgpack``-framed binary per step directory + a JSON manifest —
no external checkpoint libraries (offline container).
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.psi import QuantizedTensor, make_format


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, QuantizedTensor):
        # Typed serving leaf: persist storage + scale plus a "@psi" metadata
        # record (bits, packed, n_psi, max_exp) so restore rebuilds the
        # QuantizedTensor with its *exact* PsiFormat — including custom
        # registrations whose term budget differs from the default — and the
        # pytree structure survives the disk round-trip
        # (restore-with-shardings tree_maps against spec trees).
        out[prefix + "@psi"] = np.asarray(
            [tree.fmt.bits, int(tree.packed), tree.fmt.n_psi,
             tree.fmt.max_exp], np.int32)
        out[prefix + "data"] = np.asarray(tree.data)
        out[prefix + "scale"] = np.asarray(tree.scale)
    elif isinstance(tree, dict):
        items = tree.items()
        for k, v in items:
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}#{i}/"))
        if len(tree) == 0:
            out[prefix + "@emptylist"] = np.zeros((0,), np.int8)
        if isinstance(tree, tuple):
            out[prefix + "@tuple"] = np.zeros((0,), np.int8)
    else:
        out[prefix.rstrip("/")] = np.asarray(tree)
    return out


def _unflatten(flat: Dict[str, np.ndarray]):
    root: Dict[str, Any] = {}
    for key, val in flat.items():
        parts = key.split("/")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val

    def rebuild(node):
        if not isinstance(node, dict):
            return node
        keys = set(node)
        if "@psi" in keys:
            meta = [int(v) for v in node["@psi"]]
            if len(meta) != 4:
                raise ValueError(
                    f"corrupt '@psi' record (expected [bits, packed, n_psi, "
                    f"max_exp], got {meta})")
            bits, packed, n_psi, max_exp = meta
            return QuantizedTensor(
                node["data"], node["scale"],
                make_format(bits, n_psi=n_psi, max_exp=max_exp),
                bool(packed))
        is_tuple = "@tuple" in keys
        keys.discard("@tuple")
        if "@emptylist" in keys and len(keys) == 1:
            return () if is_tuple else []
        if keys and all(k.startswith("#") for k in keys):
            seq = [rebuild(node[f"#{i}"]) for i in range(len(keys))]
            return tuple(seq) if is_tuple else seq
        return {k: rebuild(v) for k, v in node.items() if k != "@tuple"}

    return rebuild(root)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, keep_every: int = 0):
        self.dir = directory
        self.keep = keep
        self.keep_every = keep_every
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree: Dict, extra: Optional[Dict] = None,
             blocking: bool = True) -> None:
        host_tree = jax.tree_util.tree_map(
            lambda x: np.asarray(jax.device_get(x)), tree)
        self.wait()
        if blocking:
            self._write(step, host_tree, extra or {})
        else:
            self._thread = threading.Thread(
                target=self._write, args=(step, host_tree, extra or {}),
                daemon=True)
            self._thread.start()

    def _write(self, step: int, host_tree, extra: Dict) -> None:
        import msgpack
        tmp = os.path.join(self.dir, f"tmp.{step}")
        final = os.path.join(self.dir, f"step_{step:010d}")
        os.makedirs(tmp, exist_ok=True)
        flat = _flatten(host_tree)
        manifest = {"step": step, "extra": extra, "arrays": {}}
        with open(os.path.join(tmp, "arrays.bin"), "wb") as f:
            for name, arr in flat.items():
                buf = np.ascontiguousarray(arr)
                manifest["arrays"][name] = {
                    "dtype": str(buf.dtype), "shape": list(buf.shape),
                    "offset": f.tell(), "nbytes": buf.nbytes}
                f.write(buf.tobytes())
            f.flush()
            os.fsync(f.fileno())
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = self.all_steps()
        protect = {s for s in steps
                   if self.keep_every and s % self.keep_every == 0}
        victims = [s for s in steps[:-self.keep] if s not in protect]
        for s in victims:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:010d}"),
                          ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self):
        pat = re.compile(r"step_(\d+)$")
        out = []
        for name in os.listdir(self.dir):
            m = pat.match(name)
            if m and os.path.exists(os.path.join(self.dir, name, "manifest.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: Optional[int] = None, shardings=None):
        """Returns (tree, extra).  ``shardings``: optional pytree (same
        structure) of jax.sharding.Sharding — leaves are device_put with the
        current mesh layout (elastic restart path)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = os.path.join(self.dir, f"step_{step:010d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        flat = {}
        with open(os.path.join(d, "arrays.bin"), "rb") as f:
            data = f.read()
        for name, meta in manifest["arrays"].items():
            arr = np.frombuffer(
                data, dtype=np.dtype(meta["dtype"]),
                count=int(np.prod(meta["shape"])) if meta["shape"] else 1,
                offset=meta["offset"]).reshape(meta["shape"])
            flat[name] = arr
        tree = _unflatten(flat)
        if shardings is not None:
            tree = jax.tree_util.tree_map(
                lambda x, s: jax.device_put(jnp.asarray(x), s),
                tree, shardings)
        return tree, manifest["extra"]
