from repro.data.pipeline import TokenStream, make_batch_for  # noqa: F401
