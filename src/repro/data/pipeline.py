"""Deterministic, resumable, shard-aware data pipeline.

Production shape: each host pulls only its shard of the global batch
(``host_id`` / ``num_hosts``), the stream is a pure function of
(seed, step, host), and the full iterator state is one integer — so
checkpoint/restore (fault tolerance) and elastic re-sharding are exact:
after a restart with a different host count, every sample is still drawn
exactly once.

The synthetic stream is a Zipf-ish token distribution with local n-gram
structure (so LM losses move during the examples' short trainings), plus
family-specific extras (vision embeds / M-RoPE positions / audio frames).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class TokenStream:
    """Stateless-per-step LM token stream; state = `step` alone."""
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    host_id: int = 0
    num_hosts: int = 1
    step: int = 0

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.num_hosts == 0
        return self.global_batch // self.num_hosts

    def _batch_at(self, step: int) -> np.ndarray:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.host_id]))
        B, S, V = self.host_batch, self.seq_len, self.vocab_size
        # Zipf marginal + order-1 structure: tok[t+1] correlated with tok[t].
        base = rng.zipf(1.3, size=(B, S)).astype(np.int64) % V
        drift = rng.integers(0, 17, size=(B, S))
        toks = (base + np.cumsum(drift, axis=1)) % V
        return toks.astype(np.int32)

    def __iter__(self) -> Iterator[np.ndarray]:
        return self

    def __next__(self) -> np.ndarray:
        b = self._batch_at(self.step)
        self.step += 1
        return b

    # ---- checkpointable state ----
    def state_dict(self) -> Dict:
        return {"step": self.step, "seed": self.seed}

    def load_state_dict(self, st: Dict) -> None:
        self.step = int(st["step"])
        self.seed = int(st["seed"])


def make_batch_for(cfg, batch: int, seq: int, key) -> Dict:
    """Family-correct random batch (used by smoke tests and examples)."""
    ks = jax.random.split(key, 4)
    out = {"tokens": jax.random.randint(ks[0], (batch, seq), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        out["vision_embeds"] = (jax.random.normal(
            ks[1], (batch, cfg.vision_patches, cfg.d_model), jnp.float32)
            * cfg.d_model ** -0.5).astype(jnp.dtype(cfg.dtype))
        out["positions"] = jnp.broadcast_to(
            jnp.arange(seq)[None, None], (batch, 3, seq))
    if cfg.family == "encdec":
        out["frames"] = (jax.random.normal(
            ks[2], (batch, cfg.enc_frames, cfg.d_model), jnp.float32)
            * cfg.d_model ** -0.5).astype(jnp.dtype(cfg.dtype))
    return out


def synthetic_mnist(n: int, seed: int = 0):
    """Procedural MNIST-like digits (no network access in this container):
    each class is a fixed stroke template + noise + random shifts.  Linearly
    separable enough for LeNet-5 to reach >95 % — which is what the Table I
    claim needs: *relative* accuracy FP32 vs PSI-quantized."""
    rng = np.random.default_rng(seed)
    templates = np.zeros((10, 32, 32), np.float32)
    for d in range(10):
        trng = np.random.default_rng(1000 + d)
        pts = trng.integers(4, 28, size=(14, 2))
        for (r, c) in pts:
            templates[d, r - 2:r + 3, c - 2:c + 3] += 0.5
        templates[d] = np.clip(templates[d], 0, 1)
    labels = rng.integers(0, 10, size=(n,))
    imgs = templates[labels]
    dr = rng.integers(-2, 3, size=(n,))
    dc = rng.integers(-2, 3, size=(n,))
    out = np.zeros((n, 32, 32, 1), np.float32)
    for i in range(n):
        out[i, :, :, 0] = np.roll(np.roll(imgs[i], dr[i], 0), dc[i], 1)
    out += rng.normal(0, 0.25, out.shape).astype(np.float32)
    return out, labels.astype(np.int32)
