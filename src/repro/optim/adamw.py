"""Optimizers (pure JAX, pytree-native): AdamW + SGD-momentum, cosine/linear
LR schedules, global-norm clipping.  Built here rather than importing optax
(offline container; also keeps the optimizer-state sharding rules trivially
derivable: moments inherit the param PartitionSpec — see runtime/sharding.py).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: dict
    v: dict


def cosine_schedule(base_lr: float, warmup: int, total: int) -> Callable:
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / jnp.maximum(warmup, 1)
        prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0, 1)
        cos = base_lr * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, cos)
    return lr


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree_util.tree_leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree_util.tree_map(lambda g: (g * scale).astype(g.dtype), grads), gn


@dataclasses.dataclass(frozen=True)
class adamw:
    lr: Callable | float = 1e-3
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    clip_norm: float = 1.0

    def init(self, params) -> AdamWState:
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamWState(
            step=jnp.zeros((), jnp.int32),
            m=jax.tree_util.tree_map(zeros, params),
            v=jax.tree_util.tree_map(zeros, params))

    def update(self, grads, state: AdamWState, params):
        grads, gnorm = clip_by_global_norm(grads, self.clip_norm)
        step = state.step + 1
        lr = self.lr(step) if callable(self.lr) else self.lr
        b1c = 1 - self.b1 ** step.astype(jnp.float32)
        b2c = 1 - self.b2 ** step.astype(jnp.float32)

        def upd(p, g, m, v):
            gf = g.astype(jnp.float32)
            m2 = self.b1 * m + (1 - self.b1) * gf
            v2 = self.b2 * v + (1 - self.b2) * gf * gf
            mh, vh = m2 / b1c, v2 / b2c
            delta = mh / (jnp.sqrt(vh) + self.eps) + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m2, v2

        flat = jax.tree_util.tree_map(upd, params, grads, state.m, state.v)
        new_p = jax.tree_util.tree_map(lambda t: t[0], flat,
                                       is_leaf=lambda t: isinstance(t, tuple))
        new_m = jax.tree_util.tree_map(lambda t: t[1], flat,
                                       is_leaf=lambda t: isinstance(t, tuple))
        new_v = jax.tree_util.tree_map(lambda t: t[2], flat,
                                       is_leaf=lambda t: isinstance(t, tuple))
        return new_p, AdamWState(step, new_m, new_v), {"grad_norm": gnorm, "lr": lr}


@dataclasses.dataclass(frozen=True)
class sgd:
    lr: Callable | float = 1e-2
    momentum: float = 0.9
    clip_norm: float = 0.0

    def init(self, params):
        return AdamWState(
            step=jnp.zeros((), jnp.int32),
            m=jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
            v={})

    def update(self, grads, state, params):
        gnorm = jnp.zeros(())
        if self.clip_norm:
            grads, gnorm = clip_by_global_norm(grads, self.clip_norm)
        step = state.step + 1
        lr = self.lr(step) if callable(self.lr) else self.lr

        def upd(p, g, m):
            m2 = self.momentum * m + g.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * m2).astype(p.dtype), m2

        flat = jax.tree_util.tree_map(upd, params, grads, state.m)
        new_p = jax.tree_util.tree_map(lambda t: t[0], flat,
                                       is_leaf=lambda t: isinstance(t, tuple))
        new_m = jax.tree_util.tree_map(lambda t: t[1], flat,
                                       is_leaf=lambda t: isinstance(t, tuple))
        return new_p, AdamWState(step, new_m, {}), {"grad_norm": gnorm, "lr": lr}
