"""INT8 gradient compression with error feedback — the distributed-
optimization trick for the cross-pod all-reduce (DESIGN.md §5).

The same PSI insight that compresses weights applies to gradient traffic: the
data-parallel all-reduce payload dominates cross-pod ICI at (2,16,16) scale.
Gradients are quantized to int8 (per-leaf symmetric scale) before the
all-reduce and the quantization residual is carried to the next step
(error feedback — keeps SGD convergence; Seide et al. 2014, Karimireddy et
al. 2019).  4x payload reduction vs f32, 2x vs bf16.

Usage (in the train step, around the psum / before optimizer.update):
    cg, new_err = compress_gradients(grads, err)
    cg = jax.lax.psum(cg_int_as_float…)        # or jit-level sharding
    grads = decompress_gradients(cg)
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def _compress_leaf(g, e):
    gf = g.astype(jnp.float32) + e
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    err = gf - q.astype(jnp.float32) * scale
    return {"q": q, "scale": scale}, err


def compress_gradients(grads, err_state=None) -> Tuple[dict, dict]:
    """Returns (compressed_tree, new_error_feedback_tree)."""
    if err_state is None:
        err_state = jax.tree_util.tree_map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads)
    pairs = jax.tree_util.tree_map(_compress_leaf, grads, err_state)
    comp = jax.tree_util.tree_map(lambda t: t[0], pairs,
                                  is_leaf=lambda t: isinstance(t, tuple))
    err = jax.tree_util.tree_map(lambda t: t[1], pairs,
                                 is_leaf=lambda t: isinstance(t, tuple))
    return comp, err


def decompress_gradients(comp):
    return jax.tree_util.tree_map(
        lambda leaf: leaf["q"].astype(jnp.float32) * leaf["scale"],
        comp, is_leaf=lambda l: isinstance(l, dict) and "q" in l)


def compressed_bytes(comp) -> int:
    return sum(l.size * l.dtype.itemsize
               for l in jax.tree_util.tree_leaves(comp))
