from repro.optim.adamw import adamw, sgd, cosine_schedule, clip_by_global_norm  # noqa: F401
from repro.optim.compress import compress_gradients, decompress_gradients  # noqa: F401
