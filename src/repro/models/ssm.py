"""Mamba-1 selective-state-space block (falcon-mamba-7b).

Parallel (train/prefill) mode uses a chunked associative scan: the sequence is
split into chunks; within a chunk ``jax.lax.associative_scan`` runs the linear
recurrence in O(log chunk) depth, and a tiny sequential ``lax.scan`` carries
the (B, d_inner, N) state across chunks.  Peak live state tensor is
(B, chunk, d_inner, N) — with d_inner sharded over the "model" mesh axis the
recurrence is fully elementwise in d, so this layer needs **zero collectives**
(the roofline table shows it; DESIGN.md §5).

Decode mode is the O(1) recurrent update on carried (conv_state, ssm_state).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.quant import linear

CHUNK = 256


def init_mamba(cfg, key):
    d, di = cfg.d_model, cfg.d_inner
    r, N, cw = cfg.resolved_dt_rank, cfg.ssm_state, cfg.ssm_conv
    ks = jax.random.split(key, 6)
    s = d ** -0.5
    return {
        "in_proj": jax.random.normal(ks[0], (d, 2 * di), jnp.float32) * s,
        "conv1d_w": jax.random.normal(ks[1], (cw, di), jnp.float32) * 0.1,
        "conv1d_b": jnp.zeros((di,), jnp.float32),
        "x_proj": jax.random.normal(ks[2], (di, r + 2 * N), jnp.float32) * di ** -0.5,
        "dt_proj_w": jax.random.normal(ks[3], (r, di), jnp.float32) * r ** -0.5,
        "dt_proj_b": jnp.log(jnp.exp(
            jax.random.uniform(ks[4], (di,), jnp.float32, 1e-3, 1e-1)) - 1 + 1e-9),
        "a_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, N + 1, dtype=jnp.float32), (di, N))),
        "d_skip": jnp.ones((di,), jnp.float32),
        "out_proj": jax.random.normal(ks[5], (di, d), jnp.float32) * di ** -0.5,
    }


def _ssm_inputs(p, xz, cfg):
    """Common pre-scan computation.  xz (B, S, di) post-conv activations.
    Returns dA (B,S,di,N), dBx (B,S,di,N), C (B,S,N)."""
    N = cfg.ssm_state
    r = cfg.resolved_dt_rank
    dbl = linear(p["x_proj"], xz, cfg.quant_mode)                  # (B,S,r+2N)
    dt, Bm, Cm = jnp.split(dbl, [r, r + N], axis=-1)
    dt = linear(p["dt_proj_w"], dt, cfg.quant_mode) + p["dt_proj_b"]
    dt = jax.nn.softplus(dt.astype(jnp.float32))                   # (B,S,di)
    A = -jnp.exp(p["a_log"])                                       # (di,N)
    dA = jnp.exp(dt[..., None] * A)                                # (B,S,di,N)
    dBx = (dt * xz.astype(jnp.float32))[..., None] * Bm.astype(jnp.float32)[:, :, None, :]
    return dA, dBx, Cm.astype(jnp.float32)


def _conv_causal(p, x, cfg):
    """Depthwise causal conv1d over seq.  x (B, S, di)."""
    cw = cfg.ssm_conv
    w = p["conv1d_w"]                                              # (cw, di)
    xp = jnp.pad(x, ((0, 0), (cw - 1, 0), (0, 0)))
    y = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(cw))
    return y + p["conv1d_b"]


def mamba_block(p, x, cfg, ssm_state=None, conv_state=None):
    """Full-sequence (train/prefill) mamba block.  x (B, S, d).
    Returns (y, (ssm_state, conv_state)) — final states for decode handoff.

    The selective scan is chunked AND the per-step inputs (dt, B, C, dA,
    dBx) are computed *inside* each checkpointed chunk: only the (B, S, di)
    post-conv activations cross the chunk boundary, so no (B, S, di, N) f32
    tensor is ever live (the full-seq formulation held several: tens of
    GB/device at train_4k scale)."""
    B, S, _ = x.shape
    di = cfg.d_inner
    xz = linear(p["in_proj"], x, cfg.quant_mode)                   # (B,S,2di)
    xs, z = jnp.split(xz, 2, axis=-1)
    # last (cw-1) pre-conv activations: decode-handoff conv state
    conv_tail = xs[:, -(cfg.ssm_conv - 1):, :].astype(jnp.float32)
    xs = jax.nn.silu(_conv_causal(p, xs, cfg))
    h0 = (jnp.zeros((B, di, cfg.ssm_state), jnp.float32)
          if ssm_state is None else ssm_state)

    n = max(S // CHUNK, 1)
    c = S // n
    xs_c = xs.reshape(B, n, c, di).transpose(1, 0, 2, 3)          # (n,B,c,di)

    @jax.checkpoint
    def chunk_step(h, xs_chunk):
        dA, dBx, Cm = _ssm_inputs(p, xs_chunk, cfg)               # (B,c,di,N)
        b0 = dBx.at[:, 0].add(dA[:, 0] * h)
        def comb(l, r):
            return (l[0] * r[0], r[0] * l[1] + r[1])
        _, hs = jax.lax.associative_scan(comb, (dA, b0), axis=1)
        y_c = jnp.einsum("bsdn,bsn->bsd", hs, Cm,
                         preferred_element_type=jnp.float32)
        y_c = (y_c + xs_chunk.astype(jnp.float32) * p["d_skip"]
               ).astype(x.dtype)
        return hs[:, -1], y_c

    h_last, ys = jax.lax.scan(chunk_step, h0, xs_c)
    y = ys.transpose(1, 0, 2, 3).reshape(B, S, di)
    y = y * jax.nn.silu(z)
    out = linear(p["out_proj"], y, cfg.quant_mode)
    return out, (h_last, conv_tail)


def init_mamba_state(cfg, batch):
    return {
        "ssm": jnp.zeros((batch, cfg.d_inner, cfg.ssm_state), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, cfg.d_inner), jnp.float32),
    }


def mamba_decode_step(p, x, cfg, state):
    """One-token recurrent update.  x (B, 1, d); state dict from
    ``init_mamba_state``.  Returns (y (B,1,d), new_state)."""
    B = x.shape[0]
    xz = linear(p["in_proj"], x, cfg.quant_mode)
    xs, z = jnp.split(xz, 2, axis=-1)                              # (B,1,di)
    conv_buf = jnp.concatenate([state["conv"], xs.astype(jnp.float32)], axis=1)
    w = p["conv1d_w"]                                              # (cw, di)
    xc = jnp.einsum("bcd,cd->bd", conv_buf, w) + p["conv1d_b"]
    xc = jax.nn.silu(xc)[:, None, :]                               # (B,1,di)
    dA, dBx, Cm = _ssm_inputs(p, xc.astype(x.dtype), cfg)
    h = state["ssm"] * dA[:, 0] + dBx[:, 0]                        # (B,di,N)
    y = jnp.einsum("bdn,bn->bd", h, Cm[:, 0],
                   preferred_element_type=jnp.float32)
    y = (y + xc[:, 0].astype(jnp.float32) * p["d_skip"]).astype(x.dtype)
    y = y[:, None, :] * jax.nn.silu(z)
    out = linear(p["out_proj"], y, cfg.quant_mode)
    return out, {"ssm": h, "conv": conv_buf[:, 1:, :]}
