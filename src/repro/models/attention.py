"""Attention: MHA/GQA/MQA, causal + sliding-window masking, qk-norm, RoPE
variants, chunked (memory-bounded) prefill, ring-buffer KV cache for decode.

Memory discipline: prefill/train never materializes the full (S, S) score
matrix — queries are processed in chunks of ``Q_CHUNK`` via ``lax.scan`` so
the peak live score tensor is (B, H, Q_CHUNK, S) regardless of sequence
length (this is what makes the 32k-prefill cells fit HBM; see EXPERIMENTS.md
§Dry-run).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.models import layers
from repro.quant import linear

Q_CHUNK = 512
NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Params.
# ---------------------------------------------------------------------------
def init_attention(cfg, key, cross=False):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    hq, hkv = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 4)
    s = d ** -0.5
    p = {
        "wq": jax.random.normal(ks[0], (d, hq * hd), jnp.float32) * s,
        "wk": jax.random.normal(ks[1], (d, hkv * hd), jnp.float32) * s,
        "wv": jax.random.normal(ks[2], (d, hkv * hd), jnp.float32) * s,
        "wo": jax.random.normal(ks[3], (hq * hd, d), jnp.float32) * (hq * hd) ** -0.5,
    }
    if cfg.qk_norm and not cross:
        p["q_norm_scale"] = jnp.ones((hd,), jnp.float32)
        p["k_norm_scale"] = jnp.ones((hd,), jnp.float32)
    return p


# ---------------------------------------------------------------------------
# Core grouped scaled-dot-product with masking.
# ---------------------------------------------------------------------------
def _grouped_scores(q, k):
    """q (B, Sq, Hq, D), k (B, Skv, Hkv, D) -> (B, Hq, Sq, Skv) f32."""
    B, Sq, Hq, D = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Sq, Hkv, G, D)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k,
                   preferred_element_type=jnp.float32)
    return s.reshape(B, Hq, Sq, k.shape[1]) * (D ** -0.5)


def _weighted_values(probs, v, Hq):
    """probs (B, Hq, Sq, Skv) f32, v (B, Skv, Hkv, D) -> (B, Sq, Hq, D)."""
    B, _, Sq, Skv = probs.shape
    Hkv, D = v.shape[2], v.shape[3]
    G = Hq // Hkv
    pg = probs.reshape(B, Hkv, G, Sq, Skv)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", pg.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, Sq, Hq, D).astype(v.dtype)


def _mask(q_pos, k_pos, causal, window):
    """(…, Sq, Skv) boolean validity mask from absolute positions."""
    # k_pos == -1 marks empty ring-buffer slots -> always invalid.
    m = jnp.broadcast_to(k_pos[..., None, :] >= 0,
                         q_pos.shape[:-1] + (q_pos.shape[-1], k_pos.shape[-1]))
    if causal:
        m &= k_pos[..., None, :] <= q_pos[..., :, None]
    if window:
        m &= k_pos[..., None, :] > q_pos[..., :, None] - window
    return m


def sdpa(q, k, v, q_pos, k_pos, *, causal=True, window=0, q_chunk=Q_CHUNK):
    """Chunked attention.  q (B,Sq,Hq,D); k,v (B,Skv,Hkv,D);
    q_pos (B,Sq), k_pos (B,Skv) absolute positions (drive causal/window
    masks — works for packed, shifted, or ring-buffer layouts alike)."""
    B, Sq, Hq, D = q.shape

    def attend(q_c, qp_c):
        s = _grouped_scores(q_c, k)                               # (B,Hq,c,Skv)
        m = _mask(qp_c, k_pos, causal, window)[:, None]
        s = jnp.where(m, s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        return _weighted_values(p, v, Hq)

    if Sq <= q_chunk:
        return attend(q, q_pos)

    # Pad queries to a chunk multiple (e.g. whisper's 1500-frame encoder);
    # padded rows attend uniformly (no mask hazard) and are sliced away.
    pad = (-Sq) % q_chunk
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, ((0, 0), (0, pad)))
    Sp = Sq + pad
    n = Sp // q_chunk
    qs = q.reshape(B, n, q_chunk, Hq, D).transpose(1, 0, 2, 3, 4)
    ps = q_pos.reshape(B, n, q_chunk).transpose(1, 0, 2)

    # checkpoint each chunk: backward recomputes scores/probs per chunk
    # instead of saving (n_chunks, B, H, chunk, Skv) f32 residuals.
    attend_ckpt = jax.checkpoint(attend)

    def step(_, xs):
        q_c, qp_c = xs
        return None, attend_ckpt(q_c, qp_c)

    _, outs = jax.lax.scan(step, None, (qs, ps))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(B, Sp, Hq, D)
    return out[:, :Sq]


# ---------------------------------------------------------------------------
# Block-level apply: prefill/train and single-token decode.
# ---------------------------------------------------------------------------
def _project_qkv(p, x, cfg, positions):
    B, S, _ = x.shape
    hd, hq, hkv = cfg.resolved_head_dim, cfg.n_heads, cfg.n_kv_heads
    qm = cfg.quant_mode
    q = linear(p["wq"], x, qm).reshape(B, S, hq, hd)
    k = linear(p["wk"], x, qm).reshape(B, S, hkv, hd)
    v = linear(p["wv"], x, qm).reshape(B, S, hkv, hd)
    if "q_norm_scale" in p:
        q = layers.rms_head_norm(p["q_norm_scale"], q, cfg.norm_eps)
        k = layers.rms_head_norm(p["k_norm_scale"], k, cfg.norm_eps)
    q = layers.apply_rope(q, positions, cfg)
    k = layers.apply_rope(k, positions, cfg)
    return q, k, v


def attention_block(p, x, cfg, positions, *, causal=True, ctx=None):
    """Training / prefill self-attention.  Returns (y, (k, v, k_pos)).

    ``ctx`` (prefix-cache suffix prefill, DESIGN.md §3): an optional
    ``{"k", "v": (B, P, Hkv, D)}`` dict of already-rotated context KV
    covering absolute positions ``[0, P)`` — the shared prompt prefix
    gathered from the paged pool.  ``positions`` then starts at ``P``
    (``pos0``), queries attend over context + fresh keys with true
    absolute positions (RoPE and the causal mask are position-driven, so
    no other change is needed), and the returned state covers the fresh
    suffix only — the context blocks already live in the pool.
    """
    q, k, v = _project_qkv(p, x, cfg, positions)
    pos1d = positions[:, 0] if positions.ndim == 3 else positions
    window = cfg.window if cfg.attn_type == "swa" else 0
    k_all, v_all, kpos_all = k, v, pos1d
    if ctx is not None:
        ck, cv = ctx["k"], ctx["v"]
        cpos = jnp.broadcast_to(
            jnp.arange(ck.shape[1], dtype=pos1d.dtype)[None],
            (x.shape[0], ck.shape[1]))
        k_all = jnp.concatenate([ck.astype(k.dtype), k], axis=1)
        v_all = jnp.concatenate([cv.astype(v.dtype), v], axis=1)
        kpos_all = jnp.concatenate([cpos, pos1d], axis=1)
    o = sdpa(q, k_all, v_all, pos1d, kpos_all, causal=causal, window=window)
    B, S = x.shape[:2]
    y = linear(p["wo"], o.reshape(B, S, -1), cfg.quant_mode)
    return y, (k, v, pos1d)


def _kv_quantize(t):
    """(…, D) bf16 -> int8 codes + per-entry scale (…, 1) f32."""
    amax = jnp.maximum(jnp.max(jnp.abs(t.astype(jnp.float32)), axis=-1,
                               keepdims=True), 1e-8)
    scale = amax / 127.0
    q = jnp.clip(jnp.round(t.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale.astype(jnp.float32)


def _kv_dequantize(q, scale, dtype):
    return (q.astype(jnp.float32) * scale).astype(dtype)


def _masked_row_write(buf, bidx, slot, new_val, active):
    """Write ``new_val`` into ``buf[b, slot[b]]`` only where ``active[b]``.

    Used by continuous batching (DESIGN.md §3): free/retired decode slots
    run through the jitted step for shape stability, but their cache rows
    must stay frozen so an admitted sequence's prefilled state is the only
    thing a slot ever holds.
    """
    if active is None:
        return buf.at[bidx, slot].set(new_val)
    mask = active.reshape(active.shape[0], *([1] * (new_val.ndim - 1)))
    old = buf[bidx, slot]
    return buf.at[bidx, slot].set(jnp.where(mask, new_val, old))


def decode_attention_block(p, x, cfg, positions, cache, active=None,
                           constrain=None):
    """Single-token decode with a (ring-buffer when windowed) KV cache.

    cache: {"k","v": (B, C, Hkv, D), "k_pos": (B, C) int32 (-1 = empty)}
    — with cfg.kv_quant == "int8", k/v are int8 codes plus per-entry
    "k_scale"/"v_scale" (B, C, Hkv, 1) f32: halves the decode-dominant
    HBM read (beyond-paper; EXPERIMENTS.md §Perf).
    ``positions`` is the absolute position of the new token, (B, 1) (or
    (B, 3, 1) for mrope).  ``active`` is an optional (B,) bool mask: rows
    where it is False compute a (discarded) output but leave the cache
    untouched — the masked-decode contract of the serving engine
    (DESIGN.md §3).  ``constrain`` (executor-threaded, DESIGN.md §5)
    re-pins the updated cache to its slot-over-data serving sharding right
    after the masked scatter writes, before the cache is read back for
    attention.  Returns (y, new_cache).
    """
    q, k_new, v_new = _project_qkv(p, x, cfg, positions)
    pos1d = positions[:, 0] if positions.ndim == 3 else positions   # (B,1)
    C = cache["k"].shape[1]
    slot = pos1d[:, 0] % C                                          # ring slot
    bidx = jnp.arange(x.shape[0])
    k_pos = _masked_row_write(cache["k_pos"], bidx, slot, pos1d[:, 0], active)
    if "k_scale" in cache:
        kq, ks = _kv_quantize(k_new[:, 0])
        vq, vs = _kv_quantize(v_new[:, 0])
        new_cache = {
            "k": _masked_row_write(cache["k"], bidx, slot, kq, active),
            "v": _masked_row_write(cache["v"], bidx, slot, vq, active),
            "k_scale": _masked_row_write(cache["k_scale"], bidx, slot, ks,
                                         active),
            "v_scale": _masked_row_write(cache["v_scale"], bidx, slot, vs,
                                         active),
            "k_pos": k_pos,
        }
        if constrain is not None:
            new_cache = constrain(new_cache)
        k = _kv_dequantize(new_cache["k"], new_cache["k_scale"], x.dtype)
        v = _kv_dequantize(new_cache["v"], new_cache["v_scale"], x.dtype)
    else:
        new_cache = {
            "k": _masked_row_write(cache["k"], bidx, slot, k_new[:, 0],
                                   active),
            "v": _masked_row_write(cache["v"], bidx, slot, v_new[:, 0],
                                   active),
            "k_pos": k_pos,
        }
        if constrain is not None:
            new_cache = constrain(new_cache)
        k, v = new_cache["k"], new_cache["v"]
    window = cfg.window if cfg.attn_type == "swa" else 0
    o = sdpa(q, k, v, pos1d, k_pos, causal=True, window=window)
    y = linear(p["wo"], o.reshape(x.shape[0], 1, -1), cfg.quant_mode)
    return y, new_cache


def paged_decode_attention_block(p, x, cfg, positions, cache, block_tables,
                                 active=None, constrain=None):
    """Single-token decode against a paged block pool (DESIGN.md §3).

    cache: {"k","v": (N, bs, Hkv, D)} block pools (plus per-entry
    "k_scale"/"v_scale" (N, bs, Hkv, 1) under cfg.kv_quant == "int8"),
    where ``N = n_blocks + max_batch`` — the last ``max_batch`` blocks are
    per-slot scratch.  ``block_tables`` is (B, n_bt) int32, -1 =
    unallocated; the host guarantees the block holding position ``pos`` is
    allocated (and unique to this slot) before the step runs.

    The new token's KV is scattered to (block_tables[b, pos//bs], pos%bs);
    inactive, table-less, or table-overflowing slots (pos//bs >= n_bt)
    write to their own scratch block instead (distinct destinations, so
    the masked-decode contract needs no read-modify-write).  The read side
    goes through the routed flash-decode kernel
    (``ops.paged_decode_attention``): pool blocks are streamed one
    block-table entry at a time with key positions *synthesized* from the
    table (logical block j, offset o -> j*bs + o; unallocated -> -1), so
    stale pool contents past ``pos`` are causally masked — no stored
    k_pos, and on TPU no dense gathered temporary (DESIGN.md §3).
    """
    q, k_new, v_new = _project_qkv(p, x, cfg, positions)
    pos1d = positions[:, 0] if positions.ndim == 3 else positions   # (B,1)
    B = x.shape[0]
    N, bs = cache["k"].shape[0], cache["k"].shape[1]
    n_bt = block_tables.shape[1]
    pos = pos1d[:, 0]                                               # (B,)
    li = pos // bs
    off = pos % bs
    # a position past the table's extent must NOT clamp to the last logical
    # block — that would scatter into a physical block owned by another
    # token.  Overflow routes to the slot's scratch block like pb < 0.
    in_range = li < n_bt
    pb = jnp.take_along_axis(block_tables, jnp.minimum(li, n_bt - 1)[:, None],
                             axis=1)[:, 0]
    ok = (pb >= 0) & in_range
    if active is not None:
        ok = ok & active
    dest = jnp.where(ok, pb, N - B + jnp.arange(B, dtype=pb.dtype))

    if "k_scale" in cache:
        kq, ks = _kv_quantize(k_new[:, 0])
        vq, vs = _kv_quantize(v_new[:, 0])
        new_cache = {
            "k": cache["k"].at[dest, off].set(kq),
            "v": cache["v"].at[dest, off].set(vq),
            "k_scale": cache["k_scale"].at[dest, off].set(ks),
            "v_scale": cache["v_scale"].at[dest, off].set(vs),
        }
    else:
        new_cache = {
            "k": cache["k"].at[dest, off].set(k_new[:, 0].astype(
                cache["k"].dtype)),
            "v": cache["v"].at[dest, off].set(v_new[:, 0].astype(
                cache["v"].dtype)),
        }
    if constrain is not None:
        new_cache = constrain(new_cache)

    # full attention only: a bounded block table cannot represent a
    # wrapping SWA ring (configs.paged_capable forbids the combination)
    assert cfg.attn_type == "full", cfg.attn_type
    o = ops.paged_decode_attention(
        q[:, 0], new_cache["k"], new_cache["v"], block_tables, pos,
        k_scale=new_cache.get("k_scale"), v_scale=new_cache.get("v_scale"))
    y = linear(p["wo"], o.reshape(B, 1, -1), cfg.quant_mode)
    return y, new_cache


def paged_verify_attention_block(p, x, cfg, positions, cache, block_tables,
                                 active=None, constrain=None):
    """k-token speculative VERIFY against the paged pool (DESIGN.md
    §"Self-speculative decoding").

    ``x`` is (B, k, d): the round's feed token followed by the first k-1
    drafted tokens; ``positions`` (B, k) are their consecutive absolute
    positions.  All k new KV entries scatter first — re-writing the
    positions the draft pass filled with draft-computed KV (the re-scatter
    rollback scheme: the target pass owns those pool entries from here on,
    so a rejected tail leaves only entries that are overwritten before any
    later query can see them) — then the read side flattens the k queries
    into (B*k) rows through the SAME routed flash-decode kernel as plain
    decode (``ops.paged_decode_attention``), with per-row positions giving
    each drafted token exactly its causal prefix.  Consecutive positions
    give the k writes distinct (block, offset) destinations iff k <= the
    block size — asserted, and enforced at the CLI flag.
    """
    q, k_new, v_new = _project_qkv(p, x, cfg, positions)
    B, S = x.shape[:2]
    N, bs = cache["k"].shape[0], cache["k"].shape[1]
    n_bt = block_tables.shape[1]
    assert S <= bs, (
        f"verify width k={S} > block_size={bs}: consecutive positions would "
        f"collide in one block's offsets")
    li = positions // bs                                           # (B, k)
    off = positions % bs
    in_range = li < n_bt
    pb = jnp.take_along_axis(block_tables, jnp.minimum(li, n_bt - 1), axis=1)
    ok = (pb >= 0) & in_range
    if active is not None:
        ok = ok & active[:, None]
    scratch = N - B + jnp.arange(B, dtype=pb.dtype)[:, None]
    dest = jnp.where(ok, pb, scratch).reshape(-1)                  # (B*k,)
    offf = off.reshape(-1)

    def scat(pool, vals):                                          # (B,k,H,·)
        return pool.at[dest, offf].set(
            vals.reshape(B * S, *vals.shape[2:]).astype(pool.dtype))

    if "k_scale" in cache:
        kq, ks = _kv_quantize(k_new)
        vq, vs = _kv_quantize(v_new)
        new_cache = {
            "k": scat(cache["k"], kq),
            "v": scat(cache["v"], vq),
            "k_scale": scat(cache["k_scale"], ks),
            "v_scale": scat(cache["v_scale"], vs),
        }
    else:
        new_cache = {
            "k": scat(cache["k"], k_new),
            "v": scat(cache["v"], v_new),
        }
    if constrain is not None:
        new_cache = constrain(new_cache)

    assert cfg.attn_type == "full", cfg.attn_type
    o = ops.paged_decode_attention(
        q.reshape(B * S, *q.shape[2:]), new_cache["k"], new_cache["v"],
        jnp.repeat(block_tables, S, axis=0), positions.reshape(-1),
        k_scale=new_cache.get("k_scale"), v_scale=new_cache.get("v_scale"))
    y = linear(p["wo"], o.reshape(B, S, -1), cfg.quant_mode)
    return y, new_cache


def init_paged_kv_cache(cfg, n_total, block_size, dtype=jnp.bfloat16):
    """Block-pool KV storage for one attention layer: ``n_total`` blocks of
    ``block_size`` positions each (``n_total = n_blocks + max_batch``; the
    tail blocks are per-slot scratch).  No ``k_pos`` leaf — key positions
    are synthesized from the block table at read time."""
    hd, hkv = cfg.resolved_head_dim, cfg.n_kv_heads
    if cfg.kv_quant == "int8":
        return {
            "k": jnp.zeros((n_total, block_size, hkv, hd), jnp.int8),
            "v": jnp.zeros((n_total, block_size, hkv, hd), jnp.int8),
            "k_scale": jnp.zeros((n_total, block_size, hkv, 1), jnp.float32),
            "v_scale": jnp.zeros((n_total, block_size, hkv, 1), jnp.float32),
        }
    return {
        "k": jnp.zeros((n_total, block_size, hkv, hd), dtype),
        "v": jnp.zeros((n_total, block_size, hkv, hd), dtype),
    }


def init_kv_cache(cfg, batch, seq_len, dtype=jnp.bfloat16):
    """Cache extent: full seq for dense attention, window for SWA/local
    (bounded state is what qualifies an arch for long_500k; DESIGN.md §4)."""
    C = min(seq_len, cfg.window) if (cfg.attn_type == "swa" and cfg.window) else seq_len
    hd, hkv = cfg.resolved_head_dim, cfg.n_kv_heads
    cache = {
        "k_pos": -jnp.ones((batch, C), jnp.int32),
    }
    if cfg.kv_quant == "int8":
        cache["k"] = jnp.zeros((batch, C, hkv, hd), jnp.int8)
        cache["v"] = jnp.zeros((batch, C, hkv, hd), jnp.int8)
        cache["k_scale"] = jnp.zeros((batch, C, hkv, 1), jnp.float32)
        cache["v_scale"] = jnp.zeros((batch, C, hkv, 1), jnp.float32)
    else:
        cache["k"] = jnp.zeros((batch, C, hkv, hd), dtype)
        cache["v"] = jnp.zeros((batch, C, hkv, hd), dtype)
    return cache


def cross_attention_block(p, x, cfg, enc_kv):
    """Encoder-decoder cross attention (whisper).  enc_kv = (k, v) from the
    encoder output; no positional rotation, no mask (full visibility)."""
    B, S, _ = x.shape
    hd, hq = cfg.resolved_head_dim, cfg.n_heads
    q = linear(p["wq"], x, cfg.quant_mode).reshape(B, S, hq, hd)
    k, v = enc_kv
    Skv = k.shape[1]
    qp = jnp.zeros((B, S), jnp.int32)
    kp = jnp.zeros((B, Skv), jnp.int32)
    o = sdpa(q, k, v, qp, kp, causal=False, window=0)
    return linear(p["wo"], o.reshape(B, S, -1), cfg.quant_mode)


def project_enc_kv(p, enc_out, cfg):
    B, S, _ = enc_out.shape
    hd, hkv = cfg.resolved_head_dim, cfg.n_kv_heads
    k = linear(p["wk"], enc_out, cfg.quant_mode).reshape(B, S, hkv, hd)
    v = linear(p["wv"], enc_out, cfg.quant_mode).reshape(B, S, hkv, hd)
    return k, v
