"""AlexNet / LeNet-5 — the paper's benchmark networks (Table I / §III).

Convolution kernels ("convk") and FC weights are PSI-quantizable exactly like
the LM linears; with ``quant_mode="psi5"/"psi8"`` the forward pass computes
with PSI-projected integer weights — the bit-faithful counterpart of the TMA
NE array (whose cycle cost is modeled in ``repro.core.tma_model``).
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import psi, quantizer
from repro.quant import linear


@dataclasses.dataclass(frozen=True)
class ConvSpec:
    out_ch: int
    kernel: int
    stride: int
    pad: int
    pool: int = 1          # max-pool window (1 = none)
    groups: int = 1


@dataclasses.dataclass(frozen=True)
class CNNConfig:
    name: str
    in_shape: Tuple[int, int, int]         # (H, W, C)
    convs: Tuple[ConvSpec, ...]
    fcs: Tuple[int, ...]
    n_classes: int
    quant_mode: str = "none"


ALEXNET = CNNConfig(
    name="alexnet", in_shape=(227, 227, 3),
    convs=(ConvSpec(96, 11, 4, 0, pool=3),
           ConvSpec(256, 5, 1, 2, pool=3, groups=2),
           ConvSpec(384, 3, 1, 1),
           ConvSpec(384, 3, 1, 1, groups=2),
           ConvSpec(256, 3, 1, 1, pool=3, groups=2)),
    fcs=(4096, 4096), n_classes=1000)

LENET5 = CNNConfig(
    name="lenet5", in_shape=(32, 32, 1),
    convs=(ConvSpec(6, 5, 1, 0, pool=2),
           ConvSpec(16, 5, 1, 0, pool=2)),
    fcs=(120, 84), n_classes=10)


def init_cnn(cfg: CNNConfig, key) -> dict:
    params = {}
    H, W, C = cfg.in_shape
    keys = jax.random.split(key, len(cfg.convs) + len(cfg.fcs) + 1)
    for i, cs in enumerate(cfg.convs):
        fan_in = cs.kernel * cs.kernel * (C // cs.groups)
        params[f"conv{i}"] = {
            "convk": jax.random.normal(
                keys[i], (cs.kernel, cs.kernel, C // cs.groups, cs.out_ch),
                jnp.float32) * fan_in ** -0.5,
            "b": jnp.zeros((cs.out_ch,), jnp.float32),
        }
        H = (H + 2 * cs.pad - cs.kernel) // cs.stride + 1
        W = (W + 2 * cs.pad - cs.kernel) // cs.stride + 1
        if cs.pool > 1:
            H, W = (H - cs.pool) // 2 + 1, (W - cs.pool) // 2 + 1
        C = cs.out_ch
    dim = H * W * C
    for j, out in enumerate(tuple(cfg.fcs) + (cfg.n_classes,)):
        k = keys[len(cfg.convs) + j]
        params[f"fc{j}"] = {
            "w": jax.random.normal(k, (dim, out), jnp.float32) * dim ** -0.5,
            "b": jnp.zeros((out,), jnp.float32),
        }
        dim = out
    return params


def _maybe_q(w, quant_mode, conv=False):
    if isinstance(w, psi.QuantizedTensor):
        # serving leaf: expand through the one shared dequantize helper
        return quantizer.dequantize(w, jnp.float32)
    kind, bits = quantizer.parse_quant_mode(quant_mode)
    if kind is None:
        return w
    # float leaf + qatN/psiN mode: compute with PSI-projected weights (STE)
    axis = tuple(range(w.ndim - 1)) if conv else (w.ndim - 2,)
    return psi.fake_quant_ste(w, bits, axis)


def cnn_forward(params: dict, x: jnp.ndarray, cfg: CNNConfig) -> jnp.ndarray:
    """x (B, H, W, C) -> logits (B, n_classes)."""
    qm = cfg.quant_mode
    for i, cs in enumerate(cfg.convs):
        w = _maybe_q(params[f"conv{i}"]["convk"], qm, conv=True)
        x = jax.lax.conv_general_dilated(
            x, w, (cs.stride, cs.stride),
            [(cs.pad, cs.pad), (cs.pad, cs.pad)],
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
            feature_group_count=cs.groups)
        x = jax.nn.relu(x + params[f"conv{i}"]["b"])
        if cs.pool > 1:
            x = jax.lax.reduce_window(
                x, -jnp.inf, jax.lax.max,
                (1, cs.pool, cs.pool, 1), (1, 2, 2, 1), "VALID")
    x = x.reshape(x.shape[0], -1)
    n_fc = len(cfg.fcs) + 1
    for j in range(n_fc):
        w = _maybe_q(params[f"fc{j}"]["w"], qm)
        x = x @ w + params[f"fc{j}"]["b"]
        if j < n_fc - 1:
            x = jax.nn.relu(x)
    return x


def cnn_loss(params, batch, cfg: CNNConfig):
    logits = cnn_forward(params, batch["images"], cfg).astype(jnp.float32)
    labels = batch["labels"]
    logz = jax.nn.logsumexp(logits, -1)
    gold = jnp.take_along_axis(logits, labels[:, None], -1)[:, 0]
    loss = jnp.mean(logz - gold)
    acc = jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
    return loss, {"acc": acc}


def quantize_cnn(params: dict, bits: int = None, policy=None) -> dict:
    return quantizer.quantize_param_tree(params, bits, policy=policy)
