"""Mixture-of-Experts FFN: top-k router, capacity-based dispatch, per-expert
SwiGLU, load-balancing auxiliary loss.

Dispatch is **batch-local** (slot-loop design): all routing metadata is
(B, S)-shaped and the sorts run *per row*, so under GSPMD the batch dim
stays sharded over the data axes.  (A global flat-token argsort — the
textbook formulation — forces the SPMD partitioner to replicate
(B*S*k, d)-sized tensors: observed 233 GB/device at train_4k scale before
this design.)

Per top-k slot j (k is static, loop unrolled):
  1. per-row argsort of that slot's expert ids -> rank of each token within
     its expert group for this slot;
  2. position = rank + running per-expert occupancy from earlier slots;
  3. tokens beyond the per-row capacity C = ceil(S*k*cf/E) drop
     (GShard semantics; capacity is per sequence — the per-data-shard
     enforcement real EP systems use).
All slots scatter into one (B, E, C, d) buffer; ONE expert GEMM runs; each
slot gathers its results back weighted by its gate.

Sharding: (B: data, E: model) for qwen3-moe (128 experts -> 8/device, EP);
mixtral (8 experts < 16) shards f inside the expert GEMMs instead (TP).
The xe reshard (B,E,C,d): data -> model on E is the EP dispatch traffic.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core import psi
from repro.core.quantizer import dequantize
from repro.quant.linear import _maybe_fake_quant


def init_moe(cfg, key):
    d, f, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    s_in, s_out = d ** -0.5, f ** -0.5
    return {
        "router": jax.random.normal(ks[0], (d, E), jnp.float32) * s_in,
        "w_gate": jax.random.normal(ks[1], (E, d, f), jnp.float32) * s_in,
        "w_up": jax.random.normal(ks[2], (E, d, f), jnp.float32) * s_in,
        "w_down": jax.random.normal(ks[3], (E, f, d), jnp.float32) * s_out,
    }


def _expert_weights(p, name, cfg):
    leaf = p[name]
    if isinstance(leaf, psi.QuantizedTensor):
        # PSI serving format: expand the expert block through the one shared
        # dequantize helper (the batched becd,edf expert einsum has no
        # 2-D-weight kernel path).
        return dequantize(leaf)
    return _maybe_fake_quant(leaf, cfg.quant_mode, axis=(leaf.ndim - 2,))


def _row_ranks(eidx_slot: jnp.ndarray, E: int) -> jnp.ndarray:
    """Per-row rank of each token within its expert group.
    eidx_slot (B, S) int32 -> ranks (B, S) int32.  Sort is along S only."""
    B, S = eidx_slot.shape
    order = jnp.argsort(eidx_slot, axis=1, stable=True)          # (B, S)
    sorted_e = jnp.take_along_axis(eidx_slot, order, axis=1)
    first = jax.vmap(
        lambda se: jnp.searchsorted(se, jnp.arange(E)))(sorted_e)  # (B, E)
    first_of_mine = jnp.take_along_axis(first, sorted_e, axis=1)
    rank_sorted = jnp.arange(S)[None, :] - first_of_mine
    inv = jnp.argsort(order, axis=1)
    return jnp.take_along_axis(rank_sorted, inv, axis=1).astype(jnp.int32)


def moe_ffn(p, x, cfg, capacity_override=None):
    """x (B, S, d) -> (y (B, S, d), aux_loss scalar)."""
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k

    # router is float by default policy; dequantize is a pass-through then
    router_w = dequantize(p["router"], jnp.float32)
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), router_w)
    probs = jax.nn.softmax(logits, axis=-1)                     # (B, S, E)
    gate, eidx = jax.lax.top_k(probs, k)                        # (B, S, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # --- load-balance aux loss (Switch/GShard), global means.
    # one-hot reduction, NOT a flat scatter-add: reshaping (B,S,k) across
    # sharded dims forces the partitioner to replicate the routing tensors.
    me = probs.mean(axis=(0, 1))                                # (E,)
    ce = jnp.sum(jax.nn.one_hot(eidx, E, dtype=jnp.float32),
                 axis=(0, 1, 2)) / (B * S * k)
    aux = cfg.router_aux_weight * E * jnp.sum(me * ce)

    C = capacity_override or max(
        int(math.ceil(k * S * cfg.capacity_factor / E)), 1)

    # --- slot positions: (B, S)-shaped metadata only ---
    occupancy = jnp.zeros((B, E), jnp.int32)
    slot_all, keep_all = [], []
    for j in range(k):
        ej = eidx[:, :, j]                                      # (B, S)
        rank = _row_ranks(ej, E)
        pos = rank + jnp.take_along_axis(occupancy, ej, axis=1)
        keep = pos < C
        slot_all.append(jnp.where(keep, ej * C + pos, E * C))   # drop sentinel
        keep_all.append(keep)
        occupancy = jnp.minimum(
            occupancy + jax.vmap(
                lambda e: jnp.zeros((E,), jnp.int32).at[e].add(1))(ej), C)
    slot_all = jnp.stack(slot_all, axis=1)                      # (B, k, S)
    keep_all = jnp.stack(keep_all, axis=1)

    # --- dispatch: per-row INDEX-ONLY scatter (builds the inverse map
    # slot -> token, (E*C+1,) i32 per row) followed by one value gather.
    # vmap keeps explicit batching dims so GSPMD shards the batch axis;
    # scattering whole (S, d) rows would materialize a (B, E*C, d) u32
    # index map (observed 86 GB replicated / 5.4 GB sharded). ---
    def dispatch_row(x_row, slots_row):
        inv = jnp.full((E * C + 1,), S, jnp.int32)
        for j in range(k):
            inv = inv.at[slots_row[j]].set(jnp.arange(S, dtype=jnp.int32))
        x_pad = jnp.concatenate(
            [x_row, jnp.zeros((1, d), x.dtype)], axis=0)        # empty -> 0
        return x_pad[inv[:-1]]

    xe = jax.vmap(dispatch_row)(x, slot_all).reshape(B, E, C, d)

    # Pin expert-path layouts: batch stays on the data axes, experts on
    # "model" (EP) when E divides it, else the ffn dim takes "model" (TP
    # inside experts).  Without the pins the partitioner resolves the
    # FSDP-sharded contraction dim by REPLICATING the batch (mixtral:
    # 10.7 GB f32 expert activations x several, 118 GB/device).
    def pin(t, *tail):
        if not cfg.act_batch_axes:
            return t
        from jax.sharding import PartitionSpec as P
        return jax.lax.with_sharding_constraint(
            t, P(cfg.act_batch_axes, *tail))

    e_ax = cfg.moe_expert_axis or None
    f_ax = None if e_ax else ("model" if cfg.act_batch_axes else None)
    xe = pin(xe, e_ax, None, None)
    wg = _expert_weights(p, "w_gate", cfg).astype(x.dtype)
    wu = _expert_weights(p, "w_up", cfg).astype(x.dtype)
    wd = _expert_weights(p, "w_down", cfg).astype(x.dtype)
    g = pin(jnp.einsum("becd,edf->becf", xe, wg,
                       preferred_element_type=jnp.float32), e_ax, None, f_ax)
    u = pin(jnp.einsum("becd,edf->becf", xe, wu,
                       preferred_element_type=jnp.float32), e_ax, None, f_ax)
    h = (jax.nn.silu(g) * u).astype(x.dtype)
    ye = pin(jnp.einsum("becf,efd->becd", h, wd,
                        preferred_element_type=jnp.float32).astype(x.dtype),
             e_ax, None, None)

    # --- combine: vmap'd per-row gathers, gate-weighted ---
    gk = (gate.transpose(0, 2, 1) * keep_all).astype(x.dtype)   # (B, k, S)

    def combine_row(ye_row, slots_row, gk_row):
        y = jnp.zeros((S, d), x.dtype)
        for j in range(k):
            got = ye_row[jnp.minimum(slots_row[j], E * C - 1)]
            y = y + got * gk_row[j][:, None]
        return y

    y = jax.vmap(combine_row)(ye.reshape(B, E * C, d), slot_all, gk)
    return y, aux
