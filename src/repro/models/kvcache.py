"""Typed decode-cache pytree: one ``KVCache`` object from the attention
kernel to the scheduler (DESIGN.md §3).

Two storage layouts, selected by the static ``layout`` metadata (the
analogue of ``QuantizedTensor``'s format field — consumers dispatch on the
*object*, never on dict-key sniffing):

* ``dense``  — the slot cache: every leaf carries the slot dim, each slot
  owns a fixed ``(max_seq, ...)`` extent (ring-buffered for SWA).  Required
  for recurrent/SSM state, SWA rings, and encoder-decoder caches.
* ``paged``  — attention KV lives in a pool of fixed-size blocks
  ``(n_blocks + scratch, block_size, Hkv, head_dim)`` per layer, indexed
  through per-slot **block tables** (a ``(max_batch, n_bt)`` int32 decode
  input; ``-1`` = unallocated).  Blocks are allocated on demand by the
  scheduler's host-side ``BlockAllocator`` and freed at retirement, so the
  admissible batch is bounded by *actual* tokens, not worst-case sequence
  length.

Pool layout invariants (shared by attention/transformer/executor/serve):

* the pool's leading dim is ``n_blocks + max_batch``: the last ``max_batch``
  blocks are per-slot *scratch* — decode writes of inactive/unallocated
  slots land there (distinct per slot, so the masked-decode scatter never
  has duplicate destinations among live data);
* a physical block is owned by at most one request at a time (allocator
  invariant), so concurrent per-slot writes never collide;
* there is no stored ``k_pos`` leaf: key positions are *synthesized* from
  the block table (logical block ``j``, offset ``o`` ⇒ position
  ``j*block_size + o``; unallocated ⇒ ``-1``).  Stale pool contents are
  invisible because decode writes position ``p`` before attending at
  ``q_pos = p`` — every reachable key slot is either freshly written or
  masked by causality.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import numpy as np

DENSE = "dense"
PAGED = "paged"
LAYOUTS = (DENSE, PAGED)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class KVCache:
    """The serving decode cache as a registered pytree.

    Children: ``kv`` (the per-layer stack tree — dense leaves or block
    pools) and ``enc_out`` (whisper's encoder output, dense only).  Static
    aux: ``layout``, ``block_size``, ``n_blocks`` (usable pool blocks,
    excluding the per-slot scratch tail) — so layout survives jit,
    eval_shape, device_put, and donation unchanged, and every consumer
    dispatches on ``cache.layout`` instead of guessing from shapes.
    """
    kv: Any
    enc_out: Optional[Any] = None
    layout: str = DENSE
    block_size: int = 0
    n_blocks: int = 0

    def tree_flatten(self):
        return ((self.kv, self.enc_out),
                (self.layout, self.block_size, self.n_blocks))

    @classmethod
    def tree_unflatten(cls, aux, children):
        kv, enc_out = children
        layout, block_size, n_blocks = aux
        return cls(kv, enc_out, layout, block_size, n_blocks)

    @property
    def paged(self) -> bool:
        return self.layout == PAGED

    def replace(self, **kw) -> "KVCache":
        return dataclasses.replace(self, **kw)


def blocks_for(n_positions: int, block_size: int) -> int:
    """Blocks needed to hold ``n_positions`` cache rows (ceil division)."""
    return -(-int(n_positions) // int(block_size))


def full_blocks(n_positions: int, block_size: int) -> int:
    """Blocks COMPLETELY filled by ``n_positions`` rows (floor division) —
    the shareable span of a prompt: only fully-populated, never-again-
    written blocks may enter the prefix cache (DESIGN.md §3)."""
    return int(n_positions) // int(block_size)


def table_width(max_seq: int, block_size: int) -> int:
    """Block-table width ``n_bt``: logical blocks covering ``max_seq``."""
    return blocks_for(max_seq, block_size)


def cache_nbytes(cache) -> int:
    """Total cache bytes (works on arrays and ShapeDtypeStructs alike)."""
    return int(sum(int(np.prod(leaf.shape)) * np.dtype(leaf.dtype).itemsize
                   for leaf in jax.tree_util.tree_leaves(cache)))
