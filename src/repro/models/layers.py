"""Shared building blocks: norms, positional encodings (RoPE family), MLPs.

Pure functions over explicit parameter dicts; params are created by the
``init_*`` companions.  All matmuls route through ``repro.quant.linear`` so
PSI quantization (QAT or serving) applies uniformly (DESIGN.md §2).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.quant import linear


# ---------------------------------------------------------------------------
# Norms.
# ---------------------------------------------------------------------------
def init_norm(cfg, d, key=None):
    if cfg.norm == "layernorm":
        return {"scale": jnp.ones((d,), jnp.float32),
                "bias": jnp.zeros((d,), jnp.float32)}
    return {"scale": jnp.ones((d,), jnp.float32)}


def apply_norm(p, x, cfg):
    """f32 statistics, activation-dtype application.

    The f32 copy of x must feed ONLY the reduction (where it fuses away):
    a shared materialized f32 x lets XLA hoist `convert(saved_activation_
    stack)` out of the backward scan loop — observed as a +50 % f32 shadow
    of the remat stack (8.9 GB on granite-34b train)."""
    if cfg.norm == "layernorm":
        mu = jnp.mean(x.astype(jnp.float32), axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(x.astype(jnp.float32) - mu), axis=-1,
                       keepdims=True)
        inv = jax.lax.rsqrt(var + cfg.norm_eps)
        y = (x - mu.astype(x.dtype)) * inv.astype(x.dtype)
        return y * p["scale"].astype(x.dtype) + p["bias"].astype(x.dtype)
    ms = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(ms + cfg.norm_eps).astype(x.dtype)
    return x * inv * p["scale"].astype(x.dtype)


def rms_head_norm(scale, x, eps):
    """qk-norm: RMSNorm over the head dim, scale shared across heads."""
    xf = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * scale).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE family.
# ---------------------------------------------------------------------------
def _rope_freqs(dim, theta, dtype=jnp.float32):
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=dtype) / dim))


def _rotate(x, cos, sin):
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def apply_rope(x, positions, cfg):
    """x (B, S, H, D); positions (B, S) int32 — or (B, 3, S) for mrope.

    * "rope":   full-dim NeoX-style rotate-half.
    * "rope2d": ChatGLM scheme — RoPE on the first half of the head dims,
      pass-through on the second half.
    * "mrope":  Qwen2-VL multimodal RoPE — head dims split into 3 sections
      (t, h, w), each rotated by its own position stream.
    * "sinusoidal"/"none": handled at the embedding level; identity here.
    """
    D = x.shape[-1]
    if cfg.rope == "rope":
        freqs = _rope_freqs(D, cfg.rope_theta)
        ang = positions[..., None].astype(jnp.float32) * freqs      # (B,S,D/2)
        cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
        return _rotate(x, cos.astype(x.dtype), sin.astype(x.dtype))
    if cfg.rope == "rope2d":
        half = D // 2
        freqs = _rope_freqs(half, cfg.rope_theta)
        ang = positions[..., None].astype(jnp.float32) * freqs
        cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
        xr, xp = x[..., :half], x[..., half:]
        return jnp.concatenate(
            [_rotate(xr, cos.astype(x.dtype), sin.astype(x.dtype)), xp], axis=-1)
    if cfg.rope == "mrope":
        # positions (B, 3, S); sections (t, h, w) split D/2 freqs 2:1:1.
        freqs = _rope_freqs(D, cfg.rope_theta)                      # (D/2,)
        nf = freqs.shape[0]
        s_t, s_h = nf // 2, nf // 4
        sec = jnp.concatenate([jnp.zeros((s_t,), jnp.int32),
                               jnp.ones((s_h,), jnp.int32),
                               2 * jnp.ones((nf - s_t - s_h,), jnp.int32)])
        pos = positions[:, sec, :].astype(jnp.float32)              # (B,nf,S)
        ang = pos.transpose(0, 2, 1) * freqs                        # (B,S,nf)
        cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
        return _rotate(x, cos.astype(x.dtype), sin.astype(x.dtype))
    return x


def sinusoidal_embedding(S, D, offset=0, dtype=jnp.float32):
    pos = jnp.arange(offset, offset + S, dtype=jnp.float32)[:, None]
    return sinusoidal_from_positions(pos[None, :, 0], D, dtype)[0]


def sinusoidal_from_positions(positions, D, dtype=jnp.float32):
    """positions (B, S) -> (B, S, D); used by whisper prefill *and* decode
    (decode passes the absolute token position)."""
    freqs = jnp.exp(-jnp.log(10000.0) * jnp.arange(D // 2, dtype=jnp.float32)
                    / max(D // 2 - 1, 1))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


# ---------------------------------------------------------------------------
# MLPs.
# ---------------------------------------------------------------------------
def init_mlp(cfg, key, d=None, d_ff=None):
    d = d or cfg.d_model
    d_ff = d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = d ** -0.5
    s_out = d_ff ** -0.5
    if cfg.act in ("swiglu", "geglu"):
        return {"w_gate": jax.random.normal(k1, (d, d_ff), jnp.float32) * s_in,
                "w_up": jax.random.normal(k2, (d, d_ff), jnp.float32) * s_in,
                "w_down": jax.random.normal(k3, (d_ff, d), jnp.float32) * s_out}
    return {"w_up": jax.random.normal(k1, (d, d_ff), jnp.float32) * s_in,
            "w_down": jax.random.normal(k2, (d_ff, d), jnp.float32) * s_out}


def apply_mlp(p, x, cfg):
    qm = cfg.quant_mode
    if cfg.act in ("swiglu", "geglu"):
        g = linear(p["w_gate"], x, qm)
        u = linear(p["w_up"], x, qm)
        act = jax.nn.silu(g) if cfg.act == "swiglu" else jax.nn.gelu(g)
        return linear(p["w_down"], act * u, qm)
    h = linear(p["w_up"], x, qm)
    return linear(p["w_down"], jax.nn.gelu(h), qm)
