"""Model facade: one object per architecture config exposing

  * ``init(key)``                     -> params
  * ``loss(params, batch)``           -> (scalar, metrics)   [train_4k]
  * ``prefill(params, batch)``        -> (logits, cache)     [prefill_32k]
  * ``decode_step(params, batch)``    -> (logits, new cache) [decode_32k/long_500k]
  * ``init_cache(batch, seq_len)``    -> cache pytree
  * ``quantize(params, bits, pack)``  -> PSI serving params (the paper's
                                         technique as a first-class feature)

``batch`` layouts per family are produced by ``input_specs``/``make_batch`` in
repro.launch.dryrun / repro.data.pipeline.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.core import quantizer
from repro.models import attention, kvcache as kvc, layers, transformer
from repro.models.kvcache import KVCache
from repro.quant import embed, linear, tied_logits
from repro.runtime import sharding as shr


def _lm_positions(B, S, offset=0):
    return jnp.broadcast_to(jnp.arange(offset, offset + S)[None], (B, S))


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: object

    # ------------------------------------------------------------------ init
    def init(self, key) -> dict:
        cfg = self.cfg
        k_e, k_s, k_h, k_enc = jax.random.split(key, 4)
        params = {
            "embed": jax.random.normal(k_e, (cfg.vocab_size, cfg.d_model),
                                       jnp.float32) * cfg.d_model ** -0.5,
            "stack": transformer.init_decoder_stack(cfg, k_s),
            "norm_f": layers.init_norm(cfg, cfg.d_model),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = jax.random.normal(
                k_h, (cfg.d_model, cfg.vocab_size), jnp.float32) * cfg.d_model ** -0.5
        if cfg.family == "encdec":
            params["encoder"] = transformer.init_encoder_stack(cfg, k_enc)
            params["enc_norm_f"] = layers.init_norm(cfg, cfg.d_model)
        return params

    def quantize(self, params, bits: int = None, pack: bool = False,
                 policy=None) -> dict:
        """PSI serving format: uniform ``bits`` and/or a per-layer mixed-
        precision ``policy`` ({"embed": 8, "w_down": 4, "default": 5})."""
        return quantizer.quantize_param_tree(params, bits, pack=pack,
                                             policy=policy)

    # -------------------------------------------------------------- embedding
    def _embed_tokens(self, params, tokens, batch):
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        x = embed(params["embed"], tokens, dtype)
        if cfg.family == "vlm" and "vision_embeds" in batch:
            P = batch["vision_embeds"].shape[1]
            x = jnp.concatenate(
                [batch["vision_embeds"].astype(dtype), x[:, P:]], axis=1)
        if cfg.rope == "sinusoidal":
            pos = jnp.broadcast_to(jnp.arange(x.shape[1])[None],
                                   (x.shape[0], x.shape[1]))
            x = x + layers.sinusoidal_from_positions(pos, cfg.d_model, dtype)
        return x

    def _positions(self, batch, B, S, offset=0):
        if "positions" in batch:
            return batch["positions"]
        return _lm_positions(B, S, offset)

    def _encode(self, params, batch):
        """Whisper encoder over precomputed (stub) frame embeddings."""
        cfg = self.cfg
        frames = batch["frames"].astype(jnp.dtype(cfg.dtype))
        x = frames + layers.sinusoidal_embedding(
            frames.shape[1], cfg.d_model, dtype=frames.dtype)[None]
        x = transformer.apply_encoder_stack(params["encoder"], x, cfg)
        return layers.apply_norm(params["enc_norm_f"], x, cfg)

    def _logits(self, params, x):
        cfg = self.cfg
        if cfg.tie_embeddings:
            return tied_logits(params["embed"], x, cfg.quant_mode)
        return linear(params["lm_head"], x, cfg.quant_mode)

    # ----------------------------------------------------------- full forward
    def forward(self, params, batch, collect_cache=False, pos0=0,
                ctx_kv=None, emit_logits=True):
        """``pos0``/``ctx_kv`` (prefix-cache suffix prefill, DESIGN.md §3):
        positions start at ``pos0`` (RoPE and the causal mask are driven by
        absolute positions) and attention additionally sees the shared
        prefix KV in ``ctx_kv`` covering ``[0, pos0)``.

        ``emit_logits=False`` (chunked prefill's intermediate chunks,
        DESIGN.md §3 "SLO scheduling") skips the lm-head entirely and
        returns ``None`` logits — only the KV states matter, and the
        (S, d_model) x (d_model, V) projection is the dominant FLOP of a
        chunk that emits nothing."""
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S = tokens.shape
        enc_out = self._encode(params, batch) if cfg.family == "encdec" else None
        x = self._embed_tokens(params, tokens, batch)
        positions = self._positions(batch, B, S, offset=pos0)
        x, states, aux = transformer.apply_decoder_stack(
            params["stack"], x, cfg, positions, enc_kv=enc_out,
            collect_cache=collect_cache, ctx_kv=ctx_kv)
        x = layers.apply_norm(params["norm_f"], x, cfg)
        logits = self._logits(params, x) if emit_logits else None
        return logits, states, aux, enc_out

    def loss(self, params, batch):
        """Next-token cross-entropy (shift-inside); returns (loss, metrics).

        Sharding note: the gold logit is extracted with a fused one-hot
        einsum, NOT take_along_axis — a gather along the model-sharded vocab
        dim makes the SPMD partitioner replicate the batch dim of the f32
        logits (observed: 5x 40 GB buffers/device at train_4k scale)."""
        logits, _, aux, _ = self.forward(params, batch)
        tokens = batch["tokens"]
        lg = logits[:, :-1]                      # stay bf16: the f32 cast
        tg = tokens[:, 1:]                       # materializes (B,S,V) f32
        # max-subtracted logsumexp with f32 ACCUMULATION but bf16 storage —
        # the convert/exp chain fuses into the reduction.
        m = jax.lax.stop_gradient(jnp.max(lg, axis=-1, keepdims=True))
        ex = jnp.exp(lg - m)                 # bf16 storage (backward residual
        #                                      is (T, V) — f32 doubles it)
        logz = (jnp.log(jnp.sum(ex, axis=-1, dtype=jnp.float32))
                + m[..., 0].astype(jnp.float32))
        # gold logit via bf16 one-hot product (fuses into the reduction).
        # A/B'd against iota-compare (materializes (B,S,V) s32 buffers) and
        # vmap'd take_along_axis (+4 GB on the 256k-vocab arch): best-or-tied
        # on every architecture.
        oh = jax.nn.one_hot(tg, lg.shape[-1], dtype=lg.dtype)
        gold = jnp.sum((lg * oh).astype(jnp.float32), axis=-1)
        mask = jnp.ones_like(tg, jnp.float32)
        if self.cfg.family == "vlm" and self.cfg.vision_patches:
            # vision positions carry no next-token target
            pos = jnp.arange(tg.shape[1])[None]
            mask = jnp.broadcast_to(pos >= self.cfg.vision_patches - 1,
                                    tg.shape).astype(jnp.float32)
        ce = jnp.sum((logz - gold) * mask) / jnp.maximum(mask.sum(), 1.0)
        total = ce + aux
        return total, {"ce": ce, "aux": aux}

    # ---------------------------------------------------------------- serving
    def init_cache(self, batch, seq_len, dtype=jnp.bfloat16, mesh=None,
                   layout=None, block_size=None, n_blocks=None) -> KVCache:
        """Batched decode cache as a typed :class:`KVCache` (DESIGN.md §3).

        ``layout`` defaults to ``cfg.resolved_cache_layout``: "dense" builds
        the classic per-slot slab; "paged" builds per-layer block pools of
        ``n_blocks`` usable blocks (default: dense-equivalent capacity,
        ``batch * ceil(seq_len / block_size)``) plus ``batch`` per-slot
        scratch blocks.  With ``mesh`` (threaded in by the Executor — its
        only cache-construction path), every leaf is committed to its
        serving sharding — slot/pool dim over the data axes, heads/state
        channels over "model" (DESIGN.md §5).  ``mesh=None`` (direct model
        use, eval_shape) skips placement."""
        cfg = self.cfg
        layout = layout or cfg.resolved_cache_layout
        if layout == kvc.PAGED:
            bs = block_size or cfg.cache_block_size
            nb = (n_blocks if n_blocks is not None
                  else batch * kvc.blocks_for(seq_len, bs))
            kv = transformer.init_paged_stack_cache(cfg, nb + batch, bs,
                                                    dtype)
            cache = KVCache(kv, None, kvc.PAGED, bs, nb)
        else:
            kv = transformer.init_stack_cache(cfg, batch, seq_len, dtype)
            enc_out = (jnp.zeros((batch, cfg.enc_frames, cfg.d_model), dtype)
                       if cfg.family == "encdec" else None)
            cache = KVCache(kv, enc_out)
        if mesh is not None:
            cache = jax.device_put(cache, shr.to_shardings(
                shr.cache_specs(cfg, mesh, cache), mesh))
        return cache

    def prefill(self, params, batch, cache_len=None, true_lens=None,
                pos0=0, ctx_kv=None, emit_logits=True):
        """Forward the prompt, return (last-token logits, decode cache).

        The returned :class:`KVCache` is always DENSE layout — a
        per-sequence cache in position order.  Under the paged engine the
        executor prefills at the bucketed length and ``insert_cache``
        scatters these rows into the allocated pool blocks (DESIGN.md §3).

        ``true_lens`` (B,) int32 supports right-padded prompts (the serving
        engine's bucketed prefill, DESIGN.md §3): last-token logits are
        gathered at ``true_lens - 1`` and KV slots past the true length are
        marked empty (k_pos = -1) so decode attention never sees pad keys.
        Only attention caches can be pad-masked post-hoc — recurrent
        (rg-lru / mamba) state absorbs pad tokens, so the engine prefills
        those families at exact lengths.

        ``pos0``/``ctx_kv`` (prefix-cache SUFFIX prefill, DESIGN.md §3):
        ``batch["tokens"]`` then holds only the uncached prompt suffix,
        positions run ``[pos0, pos0 + S)`` so RoPE and the causal mask see
        true positions, attention additionally reads the shared-prefix KV
        in ``ctx_kv``, and the returned cache covers the suffix rows only
        (``true_lens`` stays suffix-relative — it indexes the suffix
        logits; the pad mask shifts by ``pos0`` internally).
        """
        cfg = self.cfg
        S = batch["tokens"].shape[1]
        cache_len = cache_len or S
        logits, states, _, enc_out = self.forward(params, batch,
                                                  collect_cache=True,
                                                  pos0=pos0, ctx_kv=ctx_kv,
                                                  emit_logits=emit_logits)
        kv = _states_to_cache(cfg, states, S, cache_len)
        enc = enc_out if cfg.family == "encdec" else None
        if true_lens is None:
            return (logits[:, -1] if emit_logits else None), KVCache(kv, enc)
        last = None
        if emit_logits:
            B = logits.shape[0]
            last = logits[jnp.arange(B), true_lens - 1]
        # k_pos entries are ABSOLUTE positions, so the pad threshold is
        # pos0 + suffix true length
        return last, KVCache(_mask_padded_kv(kv, true_lens + pos0), enc)

    def gather_prefix_ctx(self, cache: KVCache, ctx_ids, dtype=jnp.bfloat16):
        """Dense per-group context KV for the shared-prefix blocks
        ``ctx_ids`` of a PAGED engine cache (the ``ctx_kv`` input of
        :meth:`prefill`; DESIGN.md §3 "Prefix cache")."""
        if not cache.paged:
            raise ValueError("prefix context is gathered from a paged "
                             "cache; this cache is dense")
        return transformer.gather_paged_ctx(cache.kv, ctx_ids, dtype)

    def decode_step(self, params, batch, cache: KVCache, mesh=None):
        """batch: {"token": (B,1), "pos": (B,1) or "positions": (B,3,1),
        optional "active": (B,) bool, "block_table": (B, n_bt) int32 when
        ``cache.layout == "paged"``}.  Rows with ``active`` False compute a
        throwaway logit but leave their cache/state rows untouched — the
        masked-decode contract that lets the continuous-batching engine keep
        the jitted step shape-stable over free slots (DESIGN.md §3).  The
        cache layout is dispatched on the typed cache itself, so a dense
        cache (e.g. straight from ``prefill``) decodes dense regardless of
        the config's serving default.

        ``mesh`` (threaded in by the Executor) pins every masked cache write
        to its serving sharding via a block-level constraint inside the
        layer scan (DESIGN.md §5); None / one device is the unsharded path.
        """
        cfg = self.cfg
        token = batch["token"]
        x = embed(params["embed"], token, jnp.dtype(cfg.dtype))
        positions = batch.get("positions", batch.get("pos"))
        if cfg.rope == "sinusoidal":
            x = x + layers.sinusoidal_from_positions(
                positions, cfg.d_model, jnp.dtype(cfg.dtype))
        bt = batch.get("block_table") if cache.paged else None
        if cache.paged and bt is None:
            raise ValueError('paged decode needs batch["block_table"] '
                             "(B, n_bt) int32, -1 = unallocated")
        constrain = None
        if mesh is not None and mesh.size > 1:
            constrain = functools.partial(shr.constrain_block_cache, cfg,
                                          mesh, paged=cache.paged)
        enc_out = cache.enc_out
        x, new_kv = transformer.apply_decoder_stack_decode(
            params["stack"], x, cfg, positions, cache.kv, enc_kv=enc_out,
            active=batch.get("active"), constrain=constrain,
            block_tables=bt)
        x = layers.apply_norm(params["norm_f"], x, cfg)
        logits = self._logits(params, x)
        return logits[:, 0], cache.replace(kv=new_kv)

    def decode_scan(self, params, batch, cache: KVCache, length, mesh=None):
        """Fused M-step greedy decode loop with IN-KERNEL retirement
        (DESIGN.md §3 "Multi-step decode & host overlap").

        batch: {"token": (B, 1), "pos": (B, 1), "active": (B,) bool,
        "remaining": (B,) int32 — per-slot emission budget (max_new minus
        tokens already emitted), "eos_id": () int32 scalar (-1 disables;
        greedy tokens are always >= 0), optional "block_table": (B, n_bt)}.

        Each step runs the standard masked :meth:`decode_step` body, then
        applies the retirement recurrence ON DEVICE::

            remaining -= active            # this step consumed one budget
            active   &= (next != eos_id) & (remaining > 0)

        so a slot that hits EOS or exhausts max_new mid-round rides out the
        rest of the round with ``active`` False — the masked-decode contract
        freezes its cache rows, making the extra steps pure throwaway
        compute.  ``pos`` advances only on entry-active steps and ``token``
        freezes at the last live emission, so the returned carry is exactly
        the state a step-at-a-time host loop would have produced: the host
        replays the same recurrence (``scheduler.replay_round``) over the
        raw (M, B) token block to recover the bit-identical streams.  The
        block table is scan-invariant: the host pre-allocates every block
        the round can touch before dispatch (same contract as the
        speculative draft scan).

        Returns ((M, B) raw per-step greedy tokens, final carry dict with
        the same token/pos/active/remaining keys, cache).
        """
        bt = batch.get("block_table") if cache.paged else None
        if cache.paged and bt is None:
            raise ValueError('paged decode_scan needs batch["block_table"]')
        eos = batch["eos_id"]

        def step(carry, _):
            tok, p, act, rem, kv = carry
            b = {"token": tok, "pos": p, "active": act}
            if self.cfg.rope == "mrope":
                b["positions"] = jnp.broadcast_to(
                    p[:, None, :], (p.shape[0], 3, 1))
            if bt is not None:
                b["block_table"] = bt
            logits, kv = self.decode_step(params, b, kv, mesh=mesh)
            nxt = jnp.argmax(logits, -1).astype(jnp.int32)        # (B,)
            rem = rem - act.astype(jnp.int32)
            new_act = act & (nxt != eos) & (rem > 0)
            tok = jnp.where(act[:, None], nxt[:, None], tok)
            p = p + act[:, None].astype(jnp.int32)
            return (tok, p, new_act, rem, kv), nxt

        (tok, p, act, rem, cache), toks = jax.lax.scan(
            step, (batch["token"], batch["pos"], batch["active"],
                   batch["remaining"], cache), None, length=length)
        carry = {"token": tok, "pos": p, "active": act, "remaining": rem}
        return toks, carry, cache

    def verify_step(self, params, batch, cache: KVCache, mesh=None):
        """Speculative VERIFY: score k consecutive tokens per slot in one
        decode-shaped batched pass (DESIGN.md §"Self-speculative decoding").

        batch: {"tokens": (B, k) — the round's feed token then the first
        k-1 drafted tokens, "pos0": (B, 1) — the feed token's absolute
        position, optional "active": (B,) bool, "block_table": (B, n_bt)}.
        Positions run ``pos0 + [0, k)`` per row.  Returns (logits (B, k, V),
        new cache); ``argmax(logits[:, j-1])`` is the target model's greedy
        token after consuming draft j-1 — the verdict the acceptance rule
        compares drafts against.  The pass re-scatters target-computed KV
        over all k positions, replacing what the draft pass wrote (the
        rollback scheme: rejected-tail entries stay stale only until the
        next round's writes reach them, and no earlier-position query can
        ever attend to them).  Paged caches only.
        """
        cfg = self.cfg
        if not cache.paged:
            raise ValueError("speculative verify runs against the paged "
                             "cache layout only")
        tokens = batch["tokens"]
        B, S = tokens.shape
        positions = batch["pos0"] + jnp.arange(S, dtype=jnp.int32)[None]
        x = embed(params["embed"], tokens, jnp.dtype(cfg.dtype))
        if cfg.rope == "sinusoidal":
            x = x + layers.sinusoidal_from_positions(
                positions, cfg.d_model, jnp.dtype(cfg.dtype))
        constrain = None
        if mesh is not None and mesh.size > 1:
            constrain = functools.partial(shr.constrain_block_cache, cfg,
                                          mesh, paged=True)
        x, new_kv = transformer.apply_decoder_stack_verify(
            params["stack"], x, cfg, positions, cache.kv,
            batch["block_table"], active=batch.get("active"),
            constrain=constrain)
        x = layers.apply_norm(params["norm_f"], x, cfg)
        logits = self._logits(params, x)
        return logits, cache.replace(kv=new_kv)

    def slice_cache(self, cache: KVCache, row) -> KVCache:
        """Batch row ``row`` of a batched DENSE cache as a batch-1 cache
        (the counterpart of ``insert_cache`` for splitting batched
        prefills; the burst path slices the dense prefill output even when
        the engine cache is paged)."""
        if cache.paged:
            raise ValueError("slice_cache slices per-slot rows; a paged "
                             "cache has no slot rows to slice")
        kv = transformer.slice_stack_cache(cache.kv, row)
        enc = (None if cache.enc_out is None else
               jax.lax.dynamic_slice_in_dim(cache.enc_out, row, 1, axis=0))
        return cache.replace(kv=kv, enc_out=enc)

    def insert_cache(self, cache: KVCache, seq_cache: KVCache, slot,
                     block_row=None) -> KVCache:
        """Admit one prefilled sequence (batch-1 dense ``seq_cache``) into
        the engine cache (DESIGN.md §3): dense writes row ``slot`` across
        every leaf; paged scatters the sequence's rows into the pool blocks
        named by ``block_row`` (n_bt,) int32 (-1 tail entries route to the
        slot's scratch block).  ``slot`` / ``block_row`` may be traced, so
        one jitted insertion covers all slots/tables."""
        if cache.paged:
            if block_row is None:
                raise ValueError("paged insert_cache needs block_row")
            kv = transformer.insert_paged_stack_cache(
                cache.kv, seq_cache.kv, block_row, cache.n_blocks + slot)
            return cache.replace(kv=kv)
        kv = transformer.insert_stack_cache(cache.kv, seq_cache.kv, slot)
        enc = cache.enc_out
        if enc is not None:
            enc = enc.at[slot].set(seq_cache.enc_out[0].astype(enc.dtype))
        return cache.replace(kv=kv, enc_out=enc)


def _ring_layout(arr, S, C):
    """Training-layout (B, S, ...) sequence -> ring-buffer (B, C, ...) cache
    holding the last min(S, C) entries at slots pos % C.  Positions are the
    contiguous prefill range [0, S), so the layout is a pad (S <= C) or a
    roll of the tail window (S > C) — no scatter needed."""
    if S <= C:
        pad = [(0, 0), (0, C - S)] + [(0, 0)] * (arr.ndim - 2)
        return jnp.pad(arr, pad)
    tail = arr[:, -C:]
    return jnp.roll(tail, shift=(S - C) % C, axis=1)


def _states_to_cache(cfg, states, S, cache_len):
    g_states, t_states = states
    group_kinds, _, tail_kinds = transformer._stack_groups(cfg)

    def conv(kind, st, stacked):
        if st is None:
            return st
        if kind in ("attn", "xattn"):
            C = (min(cache_len, cfg.window)
                 if (cfg.attn_type == "swa" and cfg.window) else cache_len)
            def ring(a):
                return (jax.vmap(lambda x: _ring_layout(x, S, C))(a)
                        if stacked else _ring_layout(a, S, C))
            k_pos = st["k_pos"]
            kp = ring(jnp.where(k_pos >= 0, k_pos, -1)) if S <= C else ring(k_pos)
            if S < C:  # padded slots must read as empty
                if stacked:
                    mask = jnp.arange(C)[None, None] < S
                else:
                    mask = jnp.arange(C)[None] < S
                kp = jnp.where(mask, kp, -1)
            k_ring, v_ring = ring(st["k"]), ring(st["v"])
            if cfg.kv_quant == "int8":
                from repro.models.attention import _kv_quantize
                kq, ks = _kv_quantize(k_ring)
                vq, vs = _kv_quantize(v_ring)
                return {"k": kq, "v": vq, "k_scale": ks, "v_scale": vs,
                        "k_pos": kp}
            return {"k": k_ring, "v": v_ring, "k_pos": kp}
        return st  # rec / mamba states are already final

    new_g = {}
    for i, kind in enumerate(group_kinds):
        new_g[f"b{i}"] = conv(kind, g_states[f"b{i}"], stacked=True)
    new_t = [conv(kind, st, stacked=False)
             for kind, st in zip(tail_kinds, t_states)]
    return (new_g, new_t)


def _mask_padded_kv(kv_cache, true_lens):
    """Mark prefilled KV slots whose absolute position is past the true
    prompt length as empty (k_pos = -1).  Positions are absolute, so this is
    layout-independent (works for padded and SWA-rolled ring caches alike)."""
    g_cache, t_cache = kv_cache

    def fix(st, stacked):
        if not isinstance(st, dict) or "k_pos" not in st:
            return st
        tl = true_lens.reshape((1, -1, 1) if stacked else (-1, 1))
        st = dict(st)
        st["k_pos"] = jnp.where(
            (st["k_pos"] >= 0) & (st["k_pos"] < tl), st["k_pos"], -1)
        return st

    new_g = {k: fix(v, stacked=True) for k, v in g_cache.items()}
    new_t = [fix(v, stacked=False) for v in t_cache]
    return (new_g, new_t)


def build_model(cfg) -> Model:
    return Model(cfg)
