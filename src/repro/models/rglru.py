"""RG-LRU recurrent block (Griffin / recurrentgemma-9b, arXiv:2402.19427).

Block structure (Griffin §2): two parallel branches from the residual stream —
  branch 1: linear -> GeLU                            (gate)
  branch 2: linear -> causal conv1d(4) -> RG-LRU      (recurrence)
merged by elementwise product, then output projection.

RG-LRU recurrence (per channel):
  r_t = sigmoid(W_a x_t + b_a)          recurrence gate
  i_t = sigmoid(W_x x_t + b_x)          input gate
  a_t = exp(c * softplus(Lambda) * (-r_t))      in (0,1), c = 8
  h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Like the Mamba block, the recurrence is elementwise in the channel dim, so a
chunked associative scan runs it with zero cross-device collectives when
channels are sharded over "model".  PSI quantization applies to the in/out
and gate projections (DESIGN.md §4).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.ssm import CHUNK
from repro.quant import linear


def init_rglru(cfg, key):
    d, dr = cfg.d_model, cfg.resolved_d_rnn
    cw = cfg.ssm_conv
    ks = jax.random.split(key, 6)
    s = d ** -0.5
    return {
        "w_in_rec": jax.random.normal(ks[0], (d, dr), jnp.float32) * s,
        "w_in_gate": jax.random.normal(ks[1], (d, dr), jnp.float32) * s,
        "conv1d_w": jax.random.normal(ks[2], (cw, dr), jnp.float32) * 0.1,
        "conv1d_b": jnp.zeros((dr,), jnp.float32),
        "rglru_wa": jax.random.normal(ks[3], (dr, dr), jnp.float32) * dr ** -0.5,
        "rglru_wx": jax.random.normal(ks[4], (dr, dr), jnp.float32) * dr ** -0.5,
        "rglru_ba": jnp.zeros((dr,), jnp.float32),
        "rglru_bx": jnp.zeros((dr,), jnp.float32),
        "rglru_lambda": jnp.full((dr,), 0.7, jnp.float32),
        "w_out": jax.random.normal(ks[5], (dr, d), jnp.float32) * dr ** -0.5,
    }


def _conv_causal(p, x, cw):
    w = p["conv1d_w"]
    xp = jnp.pad(x, ((0, 0), (cw - 1, 0), (0, 0)))
    return sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(cw)) + p["conv1d_b"]


def _gates(p, x, cfg):
    """a_t (decay) and gated input, both (B, S, dr) f32."""
    r = jax.nn.sigmoid(linear(p["rglru_wa"], x, cfg.quant_mode)
                       .astype(jnp.float32) + p["rglru_ba"])
    i = jax.nn.sigmoid(linear(p["rglru_wx"], x, cfg.quant_mode)
                       .astype(jnp.float32) + p["rglru_bx"])
    log_a = -cfg.rglru_c * jax.nn.softplus(p["rglru_lambda"]) * r
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * x.astype(jnp.float32))
    return a, gated


def _scan_chunked(a, b, h0):
    """h_t = a_t h_{t-1} + b_t over seq; a, b (B, S, dr); h0 (B, dr)."""
    B, S, dr = a.shape
    n = max(S // CHUNK, 1)
    c = S // n
    a_c = a.reshape(B, n, c, dr).transpose(1, 0, 2, 3)
    b_c = b.reshape(B, n, c, dr).transpose(1, 0, 2, 3)

    @jax.checkpoint
    def chunk_step(h, xs):
        # checkpointed — see repro.models.ssm._scan_chunked
        ac, bc = xs
        bc0 = bc.at[:, 0].add(ac[:, 0] * h)
        def comb(l, r):
            return (l[0] * r[0], r[0] * l[1] + r[1])
        _, hs = jax.lax.associative_scan(comb, (ac, bc0), axis=1)
        return hs[:, -1], hs

    h_last, hs = jax.lax.scan(chunk_step, h0, (a_c, b_c))
    return hs.transpose(1, 0, 2, 3).reshape(B, S, dr), h_last


def rglru_block(p, x, cfg, state=None):
    """Full-sequence recurrent block.  x (B, S, d).
    Returns (y, {"h": (B,dr), "conv": (B,cw-1,dr)})."""
    B, S, _ = x.shape
    gate = jax.nn.gelu(linear(p["w_in_gate"], x, cfg.quant_mode))
    xr = linear(p["w_in_rec"], x, cfg.quant_mode)
    conv_tail = xr[:, -(cfg.ssm_conv - 1):, :].astype(jnp.float32)
    xr = _conv_causal(p, xr, cfg.ssm_conv).astype(x.dtype)
    a, b = _gates(p, xr, cfg)
    h0 = jnp.zeros((B, a.shape[-1]), jnp.float32) if state is None else state["h"]
    hs, h_last = _scan_chunked(a, b, h0)
    y = hs.astype(x.dtype) * gate
    out = linear(p["w_out"], y, cfg.quant_mode)
    return out, {"h": h_last, "conv": conv_tail}


def init_rglru_state(cfg, batch):
    dr = cfg.resolved_d_rnn
    return {"h": jnp.zeros((batch, dr), jnp.float32),
            "conv": jnp.zeros((batch, cfg.ssm_conv - 1, dr), jnp.float32)}


def rglru_decode_step(p, x, cfg, state):
    """One-token update.  x (B, 1, d)."""
    gate = jax.nn.gelu(linear(p["w_in_gate"], x, cfg.quant_mode))  # (B,1,dr)
    xr = linear(p["w_in_rec"], x, cfg.quant_mode)
    conv_buf = jnp.concatenate([state["conv"], xr.astype(jnp.float32)], axis=1)
    w = p["conv1d_w"]
    xc = (jnp.einsum("bcd,cd->bd", conv_buf, w) + p["conv1d_b"])[:, None, :]
    a, b = _gates(p, xc.astype(x.dtype), cfg)
    h = a[:, 0] * state["h"] + b[:, 0]
    y = h[:, None, :].astype(x.dtype) * gate
    out = linear(p["w_out"], y, cfg.quant_mode)
    return out, {"h": h, "conv": conv_buf[:, 1:, :]}
