"""Model stacks for every assigned family: decoder-only (dense/MoE/SSM/
hybrid), encoder-decoder (whisper), VLM backbone (qwen2-vl).

Layers are parameter-stacked and driven by ``jax.lax.scan`` (compile time is
O(1) in depth — granite's 88 layers lower as one loop).  Hybrid stacks scan
over (rec, rec, attn) groups with an unrolled remainder.  Each block is
wrapped in ``jax.checkpoint`` when cfg.remat (activation recomputation keeps
the train_4k cells inside HBM).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import attention, layers, moe, rglru, ssm
from repro.quant import linear, embed, tied_logits


# ---------------------------------------------------------------------------
# Single blocks.
# ---------------------------------------------------------------------------
def init_block(cfg, key, kind):
    ks = jax.random.split(key, 4)
    p = {"norm1": layers.init_norm(cfg, cfg.d_model)}
    if kind == "attn":
        p["attn"] = attention.init_attention(cfg, ks[0])
    elif kind == "rec":
        p["rec"] = rglru.init_rglru(cfg, ks[0])
    elif kind == "mamba":
        p["mamba"] = ssm.init_mamba(cfg, ks[0])
    elif kind == "xattn":     # decoder block with self + cross attention
        p["attn"] = attention.init_attention(cfg, ks[0])
        p["norm_x"] = layers.init_norm(cfg, cfg.d_model)
        p["xattn"] = attention.init_attention(cfg, ks[2], cross=True)
    # FFN half (mamba blocks have none; MoE blocks carry expert weights).
    if kind != "mamba":
        p["norm2"] = layers.init_norm(cfg, cfg.d_model)
        if cfg.family == "moe":
            p["moe"] = moe.init_moe(cfg, ks[1])
        else:
            p["mlp"] = layers.init_mlp(cfg, ks[1])
    return p


def apply_block(p, x, cfg, kind, positions, enc_kv=None, ctx=None):
    """Full-sequence (train / prefill) block.  Returns (x, state, aux).
    ``ctx`` is this block's prefix-cache context KV (attn blocks only;
    see ``attention.attention_block``)."""
    h = layers.apply_norm(p["norm1"], x, cfg)
    state = None
    if kind in ("attn", "xattn"):
        y, (k, v, k_pos) = attention.attention_block(p["attn"], h, cfg,
                                                     positions, ctx=ctx)
        state = {"k": k, "v": v, "k_pos": k_pos}
        x = x + y
        if kind == "xattn":
            hx = layers.apply_norm(p["norm_x"], x, cfg)
            ekv = attention.project_enc_kv(p["xattn"], enc_kv, cfg)
            x = x + attention.cross_attention_block(p["xattn"], hx, cfg, ekv)
    elif kind == "rec":
        y, state = rglru.rglru_block(p["rec"], h, cfg)
        x = x + y
    elif kind == "mamba":
        y, (h_last, conv_tail) = ssm.mamba_block(p["mamba"], h, cfg)
        state = {"ssm": h_last, "conv": conv_tail}
        x = x + y
    aux = jnp.zeros((), jnp.float32)
    if kind != "mamba":
        h2 = layers.apply_norm(p["norm2"], x, cfg)
        if cfg.family == "moe":
            y2, aux = moe.moe_ffn(p["moe"], h2, cfg)
        else:
            y2 = layers.apply_mlp(p["mlp"], h2, cfg)
        x = x + y2
    return x, state, aux


def _freeze_inactive_state(new_state, old_state, active):
    """Keep recurrent (rg-lru / mamba) state rows frozen where ``active`` is
    False — the masked-decode contract for continuous batching (DESIGN.md §3).
    State leaves all carry batch on axis 0 at block level."""
    if active is None:
        return new_state

    def sel(n, o):
        mask = active.reshape(active.shape[0], *([1] * (n.ndim - 1)))
        return jnp.where(mask, n, o)

    return jax.tree_util.tree_map(sel, new_state, old_state)


def apply_block_decode(p, x, cfg, kind, positions, cache, enc_kv=None,
                       active=None, constrain=None, block_tables=None):
    """One-token decode block.  Returns (x, new_cache).  ``active`` (B,) bool
    masks cache/state mutation per batch row (None = all rows live).
    ``constrain`` (executor-threaded, DESIGN.md §5) re-pins the block's
    updated cache to its serving sharding after the masked writes.
    ``block_tables`` (B, n_bt) selects the paged attention path — the block
    cache is then a pool dict and the read side goes through the routed
    flash-decode kernel, ``kernels.ops.paged_decode_attention`` (DESIGN.md
    §3 "Paged-decode kernel"); only pure-attention stacks resolve to the
    paged layout (configs.ModelConfig.paged_capable)."""
    h = layers.apply_norm(p["norm1"], x, cfg)
    if kind in ("attn", "xattn"):
        if block_tables is not None:
            y, cache = attention.paged_decode_attention_block(
                p["attn"], h, cfg, positions, cache, block_tables,
                active=active, constrain=constrain)
        else:
            y, cache = attention.decode_attention_block(p["attn"], h, cfg,
                                                        positions, cache,
                                                        active=active,
                                                        constrain=constrain)
        x = x + y
        if kind == "xattn":
            hx = layers.apply_norm(p["norm_x"], x, cfg)
            ekv = attention.project_enc_kv(p["xattn"], enc_kv, cfg)
            x = x + attention.cross_attention_block(p["xattn"], hx, cfg, ekv)
    elif kind == "rec":
        y, new_cache = rglru.rglru_decode_step(p["rec"], h, cfg, cache)
        cache = _freeze_inactive_state(new_cache, cache, active)
        if constrain is not None:
            cache = constrain(cache)
        x = x + y
    elif kind == "mamba":
        y, new_cache = ssm.mamba_decode_step(p["mamba"], h, cfg, cache)
        cache = _freeze_inactive_state(new_cache, cache, active)
        if constrain is not None:
            cache = constrain(cache)
        x = x + y
    if kind != "mamba":
        h2 = layers.apply_norm(p["norm2"], x, cfg)
        if cfg.family == "moe":
            y2, _ = moe.moe_ffn(p["moe"], h2, cfg)
        else:
            y2 = layers.apply_mlp(p["mlp"], h2, cfg)
        x = x + y2
    return x, cache


# ---------------------------------------------------------------------------
# Stack layout helpers.
# ---------------------------------------------------------------------------
def layer_kinds(cfg):
    """Per-layer block kind for the decoder stack."""
    if cfg.family == "ssm":
        return ["mamba"] * cfg.n_layers
    if cfg.family == "hybrid":
        pat = cfg.block_pattern
        return [pat[i % len(pat)] for i in range(cfg.n_layers)]
    if cfg.family == "encdec":
        return ["xattn"] * cfg.n_layers
    return ["attn"] * cfg.n_layers


def _stack_groups(cfg):
    """(group_kinds, n_scanned_groups, tail_kinds): scan unit for the stack."""
    kinds = layer_kinds(cfg)
    if cfg.family == "hybrid":
        pat = list(cfg.block_pattern)
        g = len(pat)
        n_groups = cfg.n_layers // g
        tail = kinds[n_groups * g:]
        return pat, n_groups, tail
    return [kinds[0]], cfg.n_layers, []


def init_decoder_stack(cfg, key):
    group_kinds, n_groups, tail_kinds = _stack_groups(cfg)
    keys = jax.random.split(key, n_groups + len(tail_kinds))

    def one_group(k):
        gks = jax.random.split(k, len(group_kinds))
        return {f"b{i}_{kind}": init_block(cfg, gk, kind)
                for i, (kind, gk) in enumerate(zip(group_kinds, gks))}

    groups = jax.tree_util.tree_map(
        lambda *xs: jnp.stack(xs), *[one_group(keys[i]) for i in range(n_groups)]
    ) if n_groups > 1 else one_group(keys[0])
    if n_groups == 1:
        groups = jax.tree_util.tree_map(lambda x: x[None], groups)
    tail = [init_block(cfg, keys[n_groups + i], kind)
            for i, kind in enumerate(tail_kinds)]
    return {"groups": groups, "tail": tail}


def _group_apply(gp, x, cfg, group_kinds, positions, enc_kv=None, ctx=None):
    states, aux = {}, jnp.zeros((), jnp.float32)
    for i, kind in enumerate(group_kinds):
        x, st, a = apply_block(gp[f"b{i}_{kind}"], x, cfg, kind, positions,
                               enc_kv,
                               ctx=None if ctx is None else ctx[f"b{i}"])
        states[f"b{i}"] = st
        aux = aux + a
    return x, states, aux


def _constrain_act(x, cfg):
    """Pin the inter-block residual stream to (batch: dp axes, seq: model,
    d: replicated).  Cuts scan-saved activations 16x and stops the SPMD
    partitioner from replicating the batch dim (DESIGN.md §5)."""
    if not cfg.act_seq_axis or x.ndim != 3 or x.shape[1] <= 1:
        return x
    from jax.sharding import PartitionSpec as P
    bax = cfg.act_batch_axes or None
    return jax.lax.with_sharding_constraint(
        x, P(bax, cfg.act_seq_axis, None))


def apply_decoder_stack(p, x, cfg, positions, enc_kv=None, collect_cache=False,
                        ctx_kv=None):
    """Returns (x, stacked_states_or_None, total_aux).

    ``ctx_kv`` (prefix-cache suffix prefill, DESIGN.md §3): a per-group
    context-KV tree in the same ``{"b{i}": {"k", "v"}}`` stacked layout as
    the decode cache (leading scanned-group axis), holding the shared
    prefix gathered from the paged pool.  Only pure-attention stacks are
    pageable, so the tail must be empty when it is supplied."""
    group_kinds, n_groups, tail_kinds = _stack_groups(cfg)
    if ctx_kv is not None:
        assert not tail_kinds, "prefix context needs a pure scanned stack"

    def body(carry, xs):
        gp, ctx = xs if ctx_kv is not None else (xs, None)
        x, aux = carry
        x = _constrain_act(x, cfg)
        x, states, a = _group_apply(gp, x, cfg, group_kinds, positions,
                                    enc_kv, ctx=ctx)
        x = _constrain_act(x, cfg)
        return (x, aux + a), (states if collect_cache else 0)

    body_fn = jax.checkpoint(body) if cfg.remat else body
    scan_xs = (p["groups"] if ctx_kv is None else (p["groups"], ctx_kv))
    if cfg.scan_layers:
        (x, aux), states = jax.lax.scan(body_fn, (x, jnp.zeros((), jnp.float32)),
                                        scan_xs)
    else:
        aux = jnp.zeros((), jnp.float32)
        collected = []
        for i in range(n_groups):
            gxs = jax.tree_util.tree_map(lambda a: a[i], scan_xs)
            (x, aux), st = body_fn((x, aux), gxs)
            collected.append(st)
        states = (jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *collected)
                  if collect_cache else None)
    tail_states = []
    for tp, kind in zip(p["tail"], tail_kinds):
        x, st, a = apply_block(tp, x, cfg, kind, positions, enc_kv)
        aux = aux + a
        tail_states.append(st)
    return x, (states, tail_states) if collect_cache else None, aux


def apply_decoder_stack_decode(p, x, cfg, positions, cache, enc_kv=None,
                               active=None, constrain=None,
                               block_tables=None):
    """cache = (group_cache_stacked, tail_cache_list) as produced by
    ``init_stack_cache`` (dense) or ``init_paged_stack_cache`` (paged —
    selected by passing ``block_tables``; the table is scan-invariant, every
    layer indexes its own pool through the same per-slot block ids).
    ``active`` (B,) bool gates cache writes per row (continuous batching;
    DESIGN.md §3).  ``constrain`` (executor-threaded) pins each block's
    updated cache to its serving sharding inside the scan (DESIGN.md §5).
    Returns (x, new_cache)."""
    group_kinds, n_groups, tail_kinds = _stack_groups(cfg)
    g_cache, t_cache = cache

    def body(x, xs):
        gp, gc = xs
        new_c = {}
        for i, kind in enumerate(group_kinds):
            x, nc = apply_block_decode(gp[f"b{i}_{kind}"], x, cfg, kind,
                                       positions, gc[f"b{i}"], enc_kv,
                                       active=active, constrain=constrain,
                                       block_tables=block_tables)
            new_c[f"b{i}"] = nc
        return x, new_c

    x, new_g_cache = jax.lax.scan(body, x, (p["groups"], g_cache))
    new_t = []
    for tp, kind, tc in zip(p["tail"], tail_kinds, t_cache):
        x, nc = apply_block_decode(tp, x, cfg, kind, positions, tc, enc_kv,
                                   active=active, constrain=constrain,
                                   block_tables=block_tables)
        new_t.append(nc)
    return x, (new_g_cache, new_t)


def apply_block_verify(p, x, cfg, positions, cache, block_tables,
                       active=None, constrain=None):
    """k-token speculative-verify block (paged attention stacks only): the
    attention half goes through ``attention.paged_verify_attention_block``;
    norms and the FFN half are shape-generic over (B, k, d)."""
    h = layers.apply_norm(p["norm1"], x, cfg)
    y, cache = attention.paged_verify_attention_block(
        p["attn"], h, cfg, positions, cache, block_tables,
        active=active, constrain=constrain)
    x = x + y
    h2 = layers.apply_norm(p["norm2"], x, cfg)
    if cfg.family == "moe":
        y2, _ = moe.moe_ffn(p["moe"], h2, cfg)
    else:
        y2 = layers.apply_mlp(p["mlp"], h2, cfg)
    return x + y2, cache


def apply_decoder_stack_verify(p, x, cfg, positions, cache, block_tables,
                               active=None, constrain=None):
    """Speculative verify over the whole stack: same scan shape as
    ``apply_decoder_stack_decode`` (the block table is scan-invariant), but
    each layer processes k tokens at once.  Paged caches exist only for
    pure full-attention stacks, so there is no tail and no kind dispatch.
    Returns (x (B, k, d), new_cache)."""
    group_kinds, n_groups, tail_kinds = _stack_groups(cfg)
    assert all(k == "attn" for k in group_kinds) and not tail_kinds, (
        f"speculative verify needs a pure attention stack, got "
        f"{group_kinds} + {tail_kinds}")
    g_cache, _ = cache

    def body(x, xs):
        gp, gc = xs
        new_c = {}
        for i, kind in enumerate(group_kinds):
            x, nc = apply_block_verify(gp[f"b{i}_{kind}"], x, cfg, positions,
                                       gc[f"b{i}"], block_tables,
                                       active=active, constrain=constrain)
            new_c[f"b{i}"] = nc
        return x, new_c

    x, new_g = jax.lax.scan(body, x, (p["groups"], g_cache))
    return x, (new_g, [])


def init_stack_cache(cfg, batch, seq_len, dtype=jnp.bfloat16):
    group_kinds, n_groups, tail_kinds = _stack_groups(cfg)

    def one(kind):
        if kind in ("attn", "xattn"):
            return attention.init_kv_cache(cfg, batch, seq_len, dtype)
        if kind == "rec":
            return rglru.init_rglru_state(cfg, batch)
        return ssm.init_mamba_state(cfg, batch)

    g = {f"b{i}": one(kind) for i, kind in enumerate(group_kinds)}
    g = jax.tree_util.tree_map(
        lambda a: jnp.broadcast_to(a[None], (n_groups,) + a.shape), g)
    t = [one(kind) for kind in tail_kinds]
    return (g, t)


def init_paged_stack_cache(cfg, n_total, block_size, dtype=jnp.bfloat16):
    """Per-layer block pools in the same (grouped, tail) stack structure as
    ``init_stack_cache``.  Only pure full-attention stacks are pageable
    (``cfg.paged_capable`` — enforced at layout resolution), so every group
    slot is an attention pool and the tail is empty."""
    group_kinds, n_groups, tail_kinds = _stack_groups(cfg)
    assert all(k == "attn" for k in group_kinds) and not tail_kinds, (
        f"paged cache needs a pure attention stack, got {group_kinds} + "
        f"{tail_kinds}")
    g = {f"b{i}": attention.init_paged_kv_cache(cfg, n_total, block_size,
                                                dtype)
         for i in range(len(group_kinds))}
    g = jax.tree_util.tree_map(
        lambda a: jnp.broadcast_to(a[None], (n_groups,) + a.shape), g)
    return (g, [])


def gather_paged_ctx(cache, ctx_ids, dtype):
    """Gather the shared-prefix blocks out of a paged stack cache as dense
    per-group context KV for the suffix prefill (DESIGN.md §3 "Prefix
    cache").

    ``cache`` is the engine's paged ``(g_cache, [])`` stack; ``ctx_ids``
    is ``(nctx,)`` int32 physical block ids covering absolute positions
    ``[0, nctx * block_size)`` in logical order.  Returns a
    ``{"b{i}": {"k", "v"}}`` tree of ``(G, 1, nctx*bs, Hkv, hd)`` arrays
    (batch-1 — the fused single-admission prefill is the only prefix
    path), int8 pools dequantized into ``dtype``.  ``nctx`` is static
    (baked into the compiled shape); ``ctx_ids`` contents are traced."""
    g_cache, tail = cache
    assert not tail, "paged caches have a pure scanned stack"

    def one(pool_dict):
        def gather(pool):
            got = pool[:, ctx_ids]               # (G, nctx, bs, Hkv, ·)
            G, n, bs = got.shape[:3]
            return got.reshape(G, 1, n * bs, *got.shape[3:])

        if "k_scale" in pool_dict:
            k = attention._kv_dequantize(gather(pool_dict["k"]),
                                         gather(pool_dict["k_scale"]), dtype)
            v = attention._kv_dequantize(gather(pool_dict["v"]),
                                         gather(pool_dict["v_scale"]), dtype)
            return {"k": k, "v": v}
        return {"k": gather(pool_dict["k"]), "v": gather(pool_dict["v"])}

    return {name: one(d) for name, d in g_cache.items()}


def insert_paged_stack_cache(cache, seq_cache, block_row, scratch_block):
    """Scatter one prefilled sequence into its allocated pool blocks.

    ``seq_cache`` is the batch-1 DENSE cache returned by ``Model.prefill``
    at ``cache_len == the prefill length`` (rows [0, C) hold the sequence in
    position order — the ring layout is the identity below the extent);
    ``cache`` is the engine's paged stack.  ``block_row`` (n_bt,) int32
    names the physical block for each logical block; entries past the
    request's own allocation are -1 and their (pad-only) rows are routed to
    ``scratch_block`` — that single destination may repeat, which is fine
    because scratch contents are never read.  ``block_row`` and
    ``scratch_block`` may be traced, so one jitted insertion serves every
    slot/table without recompiling.
    """
    g_cache, _ = cache
    sg_cache, _ = seq_cache

    def scatter(pool, seq):
        # pool (G, N, bs, ...), seq (G, 1, C, ...)
        bs = pool.shape[2]
        C = seq.shape[2]
        nb = -(-C // bs)
        rows = seq[:, 0]
        if nb * bs != C:
            pad = [(0, 0), (0, nb * bs - C)] + [(0, 0)] * (rows.ndim - 2)
            rows = jnp.pad(rows, pad)
        rows = rows.reshape(rows.shape[0], nb, bs, *rows.shape[2:])
        ids = jax.lax.dynamic_slice_in_dim(block_row, 0, nb)
        dest = jnp.where(ids >= 0, ids, scratch_block)
        return pool.at[:, dest].set(rows.astype(pool.dtype))

    new_g = {}
    for name, pool_dict in g_cache.items():
        seq_dict = sg_cache[name]
        new_g[name] = {k: scatter(pool, seq_dict[k])
                       for k, pool in pool_dict.items()}
    return (new_g, [])


def slice_stack_cache(cache, row):
    """Extract batch row ``row`` of a batched cache as a batch-1 cache
    (grouped leaves: batch axis 1; tail leaves: axis 0).  The engine uses it
    to split a batched prefill into per-slot insertions; ``row`` may be
    traced."""
    g_cache, t_cache = cache
    new_g = jax.tree_util.tree_map(
        lambda a: jax.lax.dynamic_slice_in_dim(a, row, 1, axis=1), g_cache)
    new_t = jax.tree_util.tree_map(
        lambda a: jax.lax.dynamic_slice_in_dim(a, row, 1, axis=0), t_cache)
    return (new_g, new_t)


def insert_stack_cache(cache, seq_cache, slot):
    """Write a single sequence's cache into row ``slot`` of a batched cache.

    ``seq_cache`` is a batch-1 cache (the output of ``Model.prefill`` on one
    request); ``cache`` is the engine's persistent (max_batch, ...) decode
    cache with identical tree structure.  Grouped leaves carry batch on
    axis 1 (behind the scanned group axis), tail leaves on axis 0 — this is
    the per-slot cache insertion primitive of the continuous-batching engine
    (DESIGN.md §3).  ``slot`` may be a traced int32 scalar, so one jitted
    insertion serves every slot without recompiling.
    """
    g_cache, t_cache = cache
    sg_cache, st_cache = seq_cache
    new_g = jax.tree_util.tree_map(
        lambda big, small: big.at[:, slot].set(small[:, 0].astype(big.dtype)),
        g_cache, sg_cache)
    new_t = jax.tree_util.tree_map(
        lambda big, small: big.at[slot].set(small[0].astype(big.dtype)),
        t_cache, st_cache)
    return (new_g, new_t)


# ---------------------------------------------------------------------------
# Encoder stack (whisper).
# ---------------------------------------------------------------------------
def init_encoder_stack(cfg, key):
    keys = jax.random.split(key, cfg.n_enc_layers)
    blocks = [
        {"norm1": layers.init_norm(cfg, cfg.d_model),
         "attn": attention.init_attention(cfg, k),
         "norm2": layers.init_norm(cfg, cfg.d_model),
         "mlp": layers.init_mlp(cfg, jax.random.fold_in(k, 1))}
        for k in keys
    ]
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *blocks)


def apply_encoder_stack(p, x, cfg):
    B, S, _ = x.shape
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    def body(x, bp):
        h = layers.apply_norm(bp["norm1"], x, cfg)
        hd, hq, hkv = cfg.resolved_head_dim, cfg.n_heads, cfg.n_kv_heads
        q = linear(bp["attn"]["wq"], h, cfg.quant_mode).reshape(B, S, hq, hd)
        k = linear(bp["attn"]["wk"], h, cfg.quant_mode).reshape(B, S, hkv, hd)
        v = linear(bp["attn"]["wv"], h, cfg.quant_mode).reshape(B, S, hkv, hd)
        o = attention.sdpa(q, k, v, pos, pos, causal=False, window=0)
        x = x + linear(bp["attn"]["wo"], o.reshape(B, S, -1), cfg.quant_mode)
        h2 = layers.apply_norm(bp["norm2"], x, cfg)
        x = x + layers.apply_mlp(bp["mlp"], h2, cfg)
        return x, 0

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body_fn, x, p)
    return x
