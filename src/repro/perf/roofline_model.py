"""Analytic roofline model per (arch x shape x mesh) cell.

Why analytic: XLA's ``cost_analysis()`` on a scanned (``lax.while``) module
counts each loop body ONCE — an 88-layer stack reports ~1/88th of its FLOPs.
The dry-run still proves compilability, supplies ``memory_analysis()`` (buffer
assignment is loop-aware) and the collective *inventory*; the three roofline
terms are computed here from exact per-layer GEMM/attention/recurrence
counts, multiplied out over layers, and cross-validated in
tests/test_roofline.py against ``cost_analysis`` on an UNROLLED reduced
config (scan_layers=False), where XLA's numbers are trustworthy.

All counts are per training/serving STEP, globally, then divided by chip
count; bytes honor the weight format (bf16 / PSI-INT8 1 B / PSI-INT5
0.625 B per weight — the paper's technique directly moves the memory term).
"""
from __future__ import annotations

import dataclasses
import math

from repro.configs import SHAPES, get_config

# TPU v5e hardware constants (roofline denominators).
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

def _weight_bytes(quant: str) -> float:
    """Serving bytes/param for a quant mode: bf16 for "none", else the
    registered PsiFormat's packed footprint (psi8 1 B, psi5 0.625 B,
    psi4 0.5 B, ...)."""
    if quant in ("", "none", None):
        return 2.0
    from repro.core.psi import get_format
    try:
        return get_format(quant).bytes_per_weight(packed=True)
    except ValueError:
        return 2.0


# Back-compat view of the paper's three named points (tests/docs reference).
WEIGHT_BYTES = {"none": 2.0, "psi8": 1.0, "psi5": 0.625}
ACT_B = 2            # bf16 activations
TRAIN_GEMM_FACTOR = 4.0    # fwd + remat-fwd + 2x bwd
TRAIN_WEIGHT_IO = 28.0     # bytes/param/step: 3 bf16 reads + grad + adam m,v
SERVE_ACT_RW = 8           # residual-stream reads+writes per layer (fused est)
TRAIN_ACT_RW = 20


@dataclasses.dataclass
class CellModel:
    flops: float                 # global FLOPs / step
    hbm_bytes: float             # global HBM bytes / step
    coll_bytes_per_dev: float    # ICI bytes / device / step
    notes: str = ""


def _attn_kv_len(cfg, S):
    if cfg.attn_type == "swa" and cfg.window:
        return min(cfg.window, S)
    return S


def _layer_gemm_flops(cfg, T):
    """Forward GEMM FLOPs for one block (excl. attention score/value dots)."""
    d, hd = cfg.d_model, cfg.resolved_head_dim
    fl = 0.0
    if cfg.family == "ssm":
        di, r, N = cfg.d_inner, cfg.resolved_dt_rank, cfg.ssm_state
        fl += 2 * T * d * 2 * di          # in_proj
        fl += 2 * T * cfg.ssm_conv * di   # depthwise conv
        fl += 2 * T * di * (r + 2 * N)    # x_proj
        fl += 2 * T * r * di              # dt_proj
        fl += 8 * T * di * N              # recurrence + y readout
        fl += 2 * T * di * d              # out_proj
        return fl
    # attention projections (attn / xattn / rec blocks handled by caller)
    return fl


def _attn_flops(cfg, T, S_ctx, causal=True):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    hq, hkv = cfg.n_heads, cfg.n_kv_heads
    fl = 2 * T * d * (hq + 2 * hkv) * hd      # qkv proj
    fl += 2 * T * hq * hd * d                 # out proj
    eff = (S_ctx + 1) / 2 if (causal and T > 1) else S_ctx
    fl += 2 * 2 * T * hq * hd * eff           # scores + values
    return fl


def _mlp_flops(cfg, T, d_ff=None):
    f = d_ff or cfg.d_ff
    n_mat = 3 if cfg.act in ("swiglu", "geglu") else 2
    return 2 * T * cfg.d_model * f * n_mat


def _moe_flops(cfg, T):
    fl = 2 * T * cfg.d_model * cfg.n_experts           # router
    fl += cfg.top_k * _mlp_flops(cfg, T)               # top-k experts
    return fl


def _rec_flops(cfg, T):
    d, dr = cfg.d_model, cfg.resolved_d_rnn
    fl = 2 * T * d * dr * 2          # in_rec + in_gate
    fl += 2 * T * cfg.ssm_conv * dr  # conv
    fl += 2 * T * dr * dr * 2        # rglru gates (wa, wx)
    fl += 10 * T * dr                # recurrence elementwise
    fl += 2 * T * dr * d             # out
    return fl


def forward_flops(cfg, B, S, kind):
    """Global forward FLOPs for one step of this shape."""
    T = B * S if kind != "decode" else B
    S_ctx = _attn_kv_len(cfg, S)
    total = 0.0
    kinds = _layer_kind_list(cfg)
    for k in kinds:
        if k == "attn":
            total += _attn_flops(cfg, T, S_ctx if kind == "decode" else
                                 min(S_ctx, S))
            total += _mlp_flops(cfg, T) if cfg.family != "moe" else _moe_flops(cfg, T)
        elif k == "xattn":
            total += _attn_flops(cfg, T, S_ctx if kind == "decode" else S)
            # cross attention: kv from enc_frames
            d, hd = cfg.d_model, cfg.resolved_head_dim
            total += 2 * T * d * cfg.n_heads * hd * 2
            total += 2 * 2 * T * cfg.n_heads * hd * cfg.enc_frames
            total += _mlp_flops(cfg, T)
        elif k == "rec":
            total += _rec_flops(cfg, T)
            total += _mlp_flops(cfg, T)
        elif k == "mamba":
            total += _layer_gemm_flops(cfg, T)
    # encoder (whisper): full enc stack on frames, every step
    if cfg.family == "encdec":
        Te = B * cfg.enc_frames
        for _ in range(cfg.n_enc_layers):
            total += _attn_flops(cfg, Te, cfg.enc_frames, causal=False)
            total += _mlp_flops(cfg, Te)
    total += 2 * T * cfg.d_model * cfg.vocab_size      # lm head
    return total


def decode_macs_per_token(cfg, ctx_len: int) -> float:
    """Roofline MACs to emit ONE token at context length ``ctx_len`` —
    the per-token work term of the paper's MACs/W figure of merit, and the
    numerator of serve_bench's MFU / tokens-per-joule columns:

        MFU              = macs*2 * tok_per_s / (PEAK_FLOPS * n_devices)
        tokens_per_joule = tok_per_s / watts

    One decode step for one slot is ``forward_flops(cfg, B=1, S=ctx,
    kind="decode")``; a MAC is 2 FLOPs."""
    return forward_flops(cfg, 1, max(int(ctx_len), 1), "decode") / 2.0


def _layer_kind_list(cfg):
    from repro.models.transformer import layer_kinds
    return layer_kinds(cfg)


def _tp_ars_per_layer(cfg) -> float:
    """Average full-activation TP collectives per layer, fwd+bwd (train).
    Dense/MoE block: attn-out AR + mlp-out AR, x2 for backward = 4.
    Mamba: out_proj AR only, x2 = 2.  Hybrid: weighted by pattern."""
    kinds = _layer_kind_list(cfg)
    per = {"attn": 4.0, "xattn": 6.0, "rec": 4.0, "mamba": 2.0}
    return sum(per[k] for k in kinds) / max(len(kinds), 1)


def weight_bytes_total(cfg, quant: str) -> float:
    """Serving-format parameter bytes (quant applies to GEMM weights only;
    norms/scales stay f32 — a ~0.1 % correction, ignored)."""
    n = cfg.param_count()
    return n * _weight_bytes(quant)


def active_weight_bytes(cfg, quant: str) -> float:
    return cfg.active_param_count() * _weight_bytes(quant)


def kv_cache_bytes(cfg, B, S, kv_quant: str = "") -> float:
    C = _attn_kv_len(cfg, S)
    hd = cfg.resolved_head_dim
    n_attn = sum(1 for k in _layer_kind_list(cfg) if k in ("attn", "xattn"))
    # int8 KV: 1 byte/elem + f32 scale per (slot, head) entry
    kv_b = (1 + 4 / hd) if kv_quant == "int8" else ACT_B
    kv = 2 * B * C * cfg.n_kv_heads * hd * kv_b * n_attn
    if cfg.family == "ssm":
        kv += B * cfg.d_inner * cfg.ssm_state * 4 * cfg.n_layers
    if cfg.family == "hybrid":
        n_rec = sum(1 for k in _layer_kind_list(cfg) if k == "rec")
        kv += B * cfg.resolved_d_rnn * 4 * n_rec
    return kv


def analytic_cell(arch: str, shape_name: str, quant: str = "psi8",
                  chips: int = 256, mesh_model: int = 16,
                  tp_on=None, kv_quant: str = "") -> CellModel:
    from repro.runtime.sharding import tp_enabled
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    B, S, kind = shape.global_batch, shape.seq_len, shape.kind
    tp = tp_enabled(cfg) if tp_on is None else tp_on
    T = B * S if kind != "decode" else B

    fwd = forward_flops(cfg, B, S, kind)
    if kind == "train":
        flops = fwd * TRAIN_GEMM_FACTOR
        hbm = (cfg.param_count() * TRAIN_WEIGHT_IO
               + TRAIN_ACT_RW * T * cfg.d_model * ACT_B * cfg.n_layers
               + 3 * T * cfg.vocab_size * ACT_B)          # logits fwd+bwd
        # FSDP param all-gather + grad reduce-scatter over the data axes
        data_ways = chips // mesh_model
        pbytes = 2.0 * cfg.param_count()                  # bf16
        coll_dev = 2 * pbytes / mesh_model if tp else 2 * pbytes / chips
        # TP collectives per layer on (T/data_ways, d) activations, fwd+bwd.
        # Elementwise-recurrent blocks (mamba, rg-lru) keep the channel dim
        # sharded through the scan: fewer boundary collectives.
        if tp:
            act = (T / data_ways) * cfg.d_model * ACT_B
            coll_dev += _tp_ars_per_layer(cfg) * act * cfg.n_layers
        notes = "train: 4x-fwd GEMMs (remat), FSDP gather+scatter, TP ARs"
    elif kind == "prefill":
        flops = fwd
        hbm = (active_weight_bytes(cfg, quant)
               + SERVE_ACT_RW * T * cfg.d_model * ACT_B * cfg.n_layers
               + kv_cache_bytes(cfg, B, S))               # cache write
        data_ways = chips // mesh_model
        coll_dev = 0.0
        if tp:
            act = (T / data_ways) * cfg.d_model * ACT_B
            coll_dev += (_tp_ars_per_layer(cfg) / 2) * act * cfg.n_layers
        notes = "prefill: weights once + cache write + TP ARs"
    else:  # decode
        flops = fwd
        hbm = (active_weight_bytes(cfg, quant)
               + kv_cache_bytes(cfg, B, S, kv_quant)      # cache read
               + SERVE_ACT_RW * T * cfg.d_model * ACT_B * cfg.n_layers)
        coll_dev = 0.0
        if tp:
            act = max(T / (chips // mesh_model), 1) * cfg.d_model * ACT_B
            coll_dev += (_tp_ars_per_layer(cfg) / 2) * act * cfg.n_layers
        notes = "decode: weights + full KV read per token"
    return CellModel(flops=flops, hbm_bytes=hbm,
                     coll_bytes_per_dev=coll_dev, notes=notes)


def roofline_terms(cell: CellModel, chips: int = 256) -> dict:
    t_c = cell.flops / (chips * PEAK_FLOPS)
    t_m = cell.hbm_bytes / (chips * HBM_BW)
    t_x = cell.coll_bytes_per_dev / ICI_BW
    terms = {"compute_s": t_c, "memory_s": t_m, "collective_s": t_x}
    dom = max(terms, key=terms.get)
    bound = terms[dom]
    return {**terms, "bottleneck": dom.replace("_s", ""),
            "bound_s": bound,
            "roofline_fraction": t_c / bound if bound > 0 else 0.0}
