"""Partial-Sub-Integer (PSI) quantization — the core technique of the TMA paper.

The paper (Eq. 1) decomposes the product of an integer weight ``w`` and input ``X``
into 2N signed powers of two::

    w * X = sum_k (s1_k * 2^{n1_k} * X  +  s2_k * 2^{n2_k} * X),   s in {-1, 0, 1}

* INT5 weights use 2 PSIs (N=1).  Every 5-bit integer is exactly representable
  except w in {+-11, +-13}, where the best two-term approximation errs by ~9 %
  (Table I of the paper).
* INT8 weights use 4 PSIs (N=2) and the decomposition is exact for all of
  [-128, 127].

On the TMA ASIC the decomposition removes multipliers.  On TPU (our target) the
same decomposition is used as a *weight-compression format*: the stored code is
5 or 8 bits per weight instead of 16, and the Pallas kernel reconstructs the
weight tile inside VMEM with shifts (see ``repro.kernels.psi_matmul``), cutting
HBM weight traffic — the dominant cost of memory-bound inference.

Everything here is exact-integer bookkeeping; tables are built once in numpy at
import time (32 + 256 entries) and the runtime paths are pure ``jnp``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Integer ranges per weight bit-width (paper: INT5 -> 2 PSIs, INT8 -> 4 PSIs).
# ---------------------------------------------------------------------------
INT5_MIN, INT5_MAX = -16, 15
INT8_MIN, INT8_MAX = -128, 127

N_PSI = {5: 2, 8: 4}
# Exponent range: INT5 needs 2^4 (15 = 16 - 1); INT8 needs 2^7.
MAX_EXP = {5: 4, 8: 7}


def _signed_power_values(max_exp: int) -> np.ndarray:
    """All values of s * 2^n for s in {-1,0,1}, n in [0, max_exp]."""
    powers = 2 ** np.arange(max_exp + 1)
    return np.unique(np.concatenate([[0], powers, -powers]))


@functools.lru_cache(maxsize=None)
def _best_decomposition_table(bits: int) -> np.ndarray:
    """For every integer in the INT<bits> range, the best <=N_PSI-term signed
    power-of-two decomposition (minimum absolute error; ties broken toward the
    smaller reconstructed magnitude, matching a truncating hardware rounder).

    Returns int16 array of shape (range_size, 2 * n_psi): [s_1, n_1, ..., s_N, n_N]
    indexed by (w - w_min).  Unused terms have s=0, n=0.
    """
    n_psi = N_PSI[bits]
    max_exp = MAX_EXP[bits]
    w_min = INT5_MIN if bits == 5 else INT8_MIN
    w_max = INT5_MAX if bits == 5 else INT8_MAX
    terms = []  # (value, sign, exp) including the zero term
    terms.append((0, 0, 0))
    for n in range(max_exp + 1):
        terms.append((1 << n, 1, n))
        terms.append((-(1 << n), -1, n))

    # Dynamic programming over number of terms: best_k[v] = decomposition of v
    # with exactly <= k terms.  Value space is bounded by n_psi * 2^max_exp.
    vmax = n_psi * (1 << max_exp)
    # reachable[v + vmax] = tuple of (s, n) pairs, or None
    reachable = {0: ()}
    for _ in range(n_psi):
        new = dict(reachable)
        for v, combo in reachable.items():
            for tv, ts, tn in terms[1:]:
                nv = v + tv
                if -vmax <= nv <= vmax and (nv not in new or len(new[nv]) > len(combo) + 1):
                    new[nv] = combo + ((ts, tn),)
        reachable = new

    table = np.zeros((w_max - w_min + 1, 2 * n_psi), dtype=np.int16)
    for w in range(w_min, w_max + 1):
        # pick reachable value closest to w; tie -> smaller |value|
        best_v, best_err = None, None
        for v in reachable:
            err = abs(v - w)
            if best_err is None or err < best_err or (
                err == best_err and abs(v) < abs(best_v)
            ):
                best_v, best_err = v, err
        combo = reachable[best_v]
        row = []
        for (s, n) in combo:
            row.extend([s, n])
        while len(row) < 2 * n_psi:
            row.extend([0, 0])
        table[w - w_min] = row
    return table


@functools.lru_cache(maxsize=None)
def psi_value_table(bits: int) -> np.ndarray:
    """Reconstructed integer value for every code in the INT<bits> range.

    ``psi_value_table(5)[w + 16]`` is the integer the hardware actually
    multiplies by when the stored weight is ``w`` — equal to ``w`` everywhere
    except +-11 -> +-10 and +-13 -> +-12 (the paper's ~9 % worst case).
    """
    tab = _best_decomposition_table(bits)
    signs = tab[:, 0::2].astype(np.int64)
    exps = tab[:, 1::2].astype(np.int64)
    return np.sum(signs * (1 << exps), axis=1).astype(np.int32)


def psi_decompose_int(w: jnp.ndarray, bits: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Decompose integer weights into (signs, exps), each ``(n_psi,) + w.shape``.

    Mirrors the paper's Weight-decomposition block (Fig. 6): the stored integer
    weight is decoded into the per-PSI (s, n) register values fed to the SAMs.
    """
    w_min = INT5_MIN if bits == 5 else INT8_MIN
    tab = jnp.asarray(_best_decomposition_table(bits))
    rows = tab[w.astype(jnp.int32) - w_min]
    signs = jnp.moveaxis(rows[..., 0::2], -1, 0).astype(jnp.int32)
    exps = jnp.moveaxis(rows[..., 1::2], -1, 0).astype(jnp.int32)
    return signs, exps


def psi_reconstruct(signs: jnp.ndarray, exps: jnp.ndarray) -> jnp.ndarray:
    """Inverse of :func:`psi_decompose_int` — sum of signed shifts.

    This is exactly what one SAM + the PSI-accumulation block compute.
    """
    return jnp.sum(signs * (1 << exps), axis=0).astype(jnp.int32)


def psi_project_int(w: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Project integer weights onto the PSI-representable set (what the
    hardware effectively multiplies by)."""
    w_min = INT5_MIN if bits == 5 else INT8_MIN
    tab = jnp.asarray(psi_value_table(bits))
    return tab[w.astype(jnp.int32) - w_min]


def sam_multiply(x: jnp.ndarray, signs: jnp.ndarray, exps: jnp.ndarray) -> jnp.ndarray:
    """Bit-faithful model of one SAM block (Fig. 2): mux(X, -X, 0) then barrel
    shift, one partial sub-integer per (sign, exp) pair; PSIs are then summed
    (the MOA's job).  ``x`` is the INT8 activation."""
    x = x.astype(jnp.int32)
    psis = jnp.where(signs == 0, 0, jnp.where(signs > 0, x, -x)) << exps
    return jnp.sum(psis, axis=0)


def moa_sign_extension_sum(operands: jnp.ndarray, in_bits: int, out_bits: int) -> jnp.ndarray:
    """The Appendix trick: summing sign-extended two's-complement operands is
    equivalent to summing the raw low ``in_bits`` fields and adding
    ``-(num_negative) * 2^{in_bits}``.  Returns the exact sum, computed the
    hardware's way, for validation against ``operands.sum()``.
    """
    operands = operands.astype(jnp.int32)
    num_neg = jnp.sum(operands < 0, axis=0)
    low = jnp.sum(jnp.where(operands < 0, operands + (1 << in_bits), operands), axis=0)
    total = low - (num_neg << in_bits)
    # wrap to out_bits two's complement (MOA output width)
    mod = 1 << out_bits
    wrapped = ((total % mod) + mod) % mod
    return jnp.where(wrapped >= (mod >> 1), wrapped - mod, wrapped)


# ---------------------------------------------------------------------------
# Float-weight quantization (per-channel symmetric) + QAT straight-through.
# ---------------------------------------------------------------------------
@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PsiQuantized:
    """A weight tensor in PSI format: integer codes + per-channel scale.

    ``codes`` are *already projected* onto the PSI-representable set, so
    dequantization is ``codes * scale`` — identical to what the SAM array
    computes (reconstruct-by-shifts), see DESIGN.md §2.
    """
    codes: jnp.ndarray   # int8, PSI-representable values
    scale: jnp.ndarray   # f32, broadcastable to codes.shape
    bits: int            # 5 or 8

    def dequantize(self, dtype=jnp.float32) -> jnp.ndarray:
        return (self.codes.astype(jnp.float32) * self.scale).astype(dtype)

    def tree_flatten(self):
        return (self.codes, self.scale), (self.bits,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], aux[0])


def _qmax(bits: int) -> int:
    return INT5_MAX if bits == 5 else INT8_MAX


def compute_scale(w: jnp.ndarray, bits: int, axis) -> jnp.ndarray:
    """Symmetric per-channel scale: max|w| along ``axis`` maps to qmax."""
    amax = jnp.max(jnp.abs(w), axis=axis, keepdims=True)
    return jnp.maximum(amax, 1e-8) / _qmax(bits)


def quantize_weights(w: jnp.ndarray, bits: int, axis=None) -> PsiQuantized:
    """Quantize float weights to PSI format.

    ``axis`` is the reduction axis/axes for the per-channel scale (None = per
    tensor).  The integer grid point is projected onto the PSI set, so the
    stored code is bit-identical to what the TMA hardware would compute with.
    """
    if bits not in (5, 8):
        raise ValueError(f"PSI supports INT5/INT8 weights, got {bits}")
    scale = compute_scale(w, bits, axis)
    q = jnp.clip(jnp.round(w / scale), -_qmax(bits) - 1, _qmax(bits)).astype(jnp.int32)
    q = psi_project_int(q, bits)
    return PsiQuantized(q.astype(jnp.int8), scale.astype(jnp.float32), bits)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def fake_quant_ste(w: jnp.ndarray, bits: int, axis=None) -> jnp.ndarray:
    """Quantize-dequantize with a straight-through gradient — the QAT op used
    to reproduce the paper's "trained with the proposed quantization"."""
    return quantize_weights(w, bits, axis).dequantize(w.dtype)


def _fq_fwd(w, bits, axis):
    return fake_quant_ste(w, bits, axis), None


def _fq_bwd(bits, axis, _res, g):
    return (g,)


fake_quant_ste.defvjp(_fq_fwd, _fq_bwd)


def quantize_activations_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-tensor INT8 activation quantization (paper §I: 8-bit
    activations).  Used by the bit-faithful reference path."""
    amax = jnp.maximum(jnp.max(jnp.abs(x)), 1e-8)
    scale = amax / 127.0
    q = jnp.clip(jnp.round(x / scale), -128, 127).astype(jnp.int8)
    return q, scale


# ---------------------------------------------------------------------------
# Sub-byte packing: INT5 codes as 5 bit-planes (exactly 5 bits/weight in HBM).
# ---------------------------------------------------------------------------
def pack_int5(codes: jnp.ndarray) -> jnp.ndarray:
    """Pack INT5 codes (..., K, N) -> uint8 bit-planes (..., 5, K//8, N).

    Bit ``b`` of weight ``codes[..., i*8 + j, n] + 16`` (offset-binary) is
    stored at bit ``j`` of ``packed[..., b, i, n]``.  K must be divisible by 8.
    Exactly 0.625 bytes per weight — the HBM footprint the psi_matmul kernel
    reads.
    """
    *lead, K, N = codes.shape
    if K % 8:
        raise ValueError(f"K={K} must be divisible by 8 for int5 packing")
    offs = (codes.astype(jnp.int32) + 16).astype(jnp.uint8)  # 0..31
    offs = offs.reshape(*lead, K // 8, 8, N)
    lane = jnp.arange(8, dtype=jnp.uint8).reshape(8, 1)
    planes = []
    for b in range(5):
        bit = (offs >> b) & 1                      # (..., K//8, 8, N)
        plane = jnp.sum(bit.astype(jnp.uint32) << lane.astype(jnp.uint32), axis=-2)
        planes.append(plane.astype(jnp.uint8))    # (..., K//8, N)
    return jnp.stack(planes, axis=-3)              # (..., 5, K//8, N)


def unpack_int5(packed: jnp.ndarray) -> jnp.ndarray:
    """Inverse of :func:`pack_int5`: (..., 5, K//8, N) uint8 -> (..., K, N) int8.

    The reconstruction is a literal sum-of-shifts (``bit << b``) — the software
    mirror of the SAM barrel shifters.
    """
    *lead, five, Kb, N = packed.shape
    assert five == 5
    lane = jnp.arange(8, dtype=jnp.uint8).reshape(1, 8, 1)
    val = jnp.zeros((*lead, Kb, 8, N), dtype=jnp.int32)
    for b in range(5):
        plane = packed[..., b, :, :][..., :, None, :]          # (..., K//8, 1, N)
        bit = (plane >> lane) & jnp.uint8(1)
        val = val + (bit.astype(jnp.int32) << b)
    codes = val.reshape(*lead, Kb * 8, N) - 16
    return codes.astype(jnp.int8)


def packed_bytes_per_weight(bits: int) -> float:
    """HBM bytes per weight in serving format (the roofline 'memory' input)."""
    return 0.625 if bits == 5 else 1.0
