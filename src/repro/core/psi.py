"""Partial-Sub-Integer (PSI) quantization — the core technique of the TMA paper.

The paper (Eq. 1) decomposes the product of an integer weight ``w`` and input ``X``
into 2N signed powers of two::

    w * X = sum_k (s1_k * 2^{n1_k} * X  +  s2_k * 2^{n2_k} * X),   s in {-1, 0, 1}

* INT5 weights use 2 PSIs (N=1).  Every 5-bit integer is exactly representable
  except w in {+-11, +-13}, where the best two-term approximation errs by ~9 %
  (Table I of the paper).
* INT8 weights use 4 PSIs (N=2) and the decomposition is exact for all of
  [-128, 127].

The paper's headline is "scalable integer weights less than 1-byte", so the
two paper points are instances of a registry: :class:`PsiFormat` describes
any width in [2, 8] bits — term budget, exponent range, derived decomposition
table, exactness + worst-case-error metadata, and sub-byte bit-plane packing.
``get_format(bits)`` / ``get_format("psi4")`` look formats up; serving weights
travel as :class:`QuantizedTensor` pytree leaves that carry their format as
static metadata, so every consumer (kernels, sharding, checkpoints) dispatches
on type + format instead of duck-typed dict keys.

On the TMA ASIC the decomposition removes multipliers.  On TPU (our target) the
same decomposition is used as a *weight-compression format*: the stored code is
``bits`` per weight instead of 16, and the Pallas kernel reconstructs the
weight tile inside VMEM with shifts (see ``repro.kernels.psi_matmul``), cutting
HBM weight traffic — the dominant cost of memory-bound inference.

Everything here is exact-integer bookkeeping; tables are built once in numpy
per registered format (lazily, <= 256 entries each) and the runtime paths are
pure ``jnp``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# PsiFormat: one registered weight width.
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class PsiFormat:
    """One PSI weight format: INT<bits> codes decomposed into <= n_psi signed
    powers of two with exponents in [0, max_exp].

    Instances are immutable and hashable — a ``QuantizedTensor`` carries its
    format as static pytree metadata (it participates in jit cache keys and
    pytree structure equality).  Error metadata is computed exhaustively at
    registration from the decomposition table, so ``worst_case_rel_error`` is
    a *certified* bound, not a declared one.
    """
    bits: int                    # stored weight width, 2..8
    n_psi: int                   # signed-power term budget (paper: 2 for
    #                              INT5, 4 for INT8)
    max_exp: int                 # exponent range [0, max_exp]
    w_min: int                   # -2^(bits-1)
    w_max: int                   # 2^(bits-1) - 1
    exact: bool                  # every code reconstructs exactly
    worst_case_rel_error: float  # max |w' - w| / max(|w|, 1) over the range

    @property
    def name(self) -> str:
        return f"psi{self.bits}"

    @property
    def qmax(self) -> int:
        return self.w_max

    @property
    def offset(self) -> int:
        """Offset-binary bias for sub-byte packing: code + offset in
        [0, 2^bits)."""
        return 1 << (self.bits - 1)

    @property
    def sub_byte(self) -> bool:
        return self.bits < 8

    def bytes_per_weight(self, packed: bool = True) -> float:
        """HBM bytes per weight in serving format (the roofline 'memory'
        input): bits/8 when bit-plane packed, one int8 byte otherwise."""
        return self.bits / 8.0 if (packed and self.sub_byte) else 1.0

    # -- derived tables (built lazily, cached per (bits, n_psi, max_exp)) --
    def decomposition_table(self) -> np.ndarray:
        return _decomposition_table(self.bits, self.n_psi, self.max_exp)

    def value_table(self) -> np.ndarray:
        return _value_table(self.bits, self.n_psi, self.max_exp)


# Term budgets per width.  The paper pins INT5 -> 2 PSIs (~9 % worst case at
# +-11/+-13) and INT8 -> 4 PSIs (exact); intermediate widths interpolate the
# same bits/2 scaling, except INT3 which needs its second term to stay exact
# (3 = 2 + 1).  Every entry's exactness/error is certified at registration.
DEFAULT_N_PSI = {2: 1, 3: 2, 4: 2, 5: 2, 6: 3, 7: 3, 8: 4}

_REGISTRY: Dict[int, PsiFormat] = {}

FormatLike = Union[int, str, PsiFormat]


def make_format(bits: int, n_psi: Optional[int] = None,
                max_exp: Optional[int] = None) -> PsiFormat:
    """Build (without registering) the PSI format for a weight width.

    Derives the integer range from ``bits``, builds the decomposition table,
    and certifies exactness / worst-case relative error exhaustively.  Used
    by :func:`register_format` and by checkpoint restore, which must rebuild
    a leaf's *exact* format (possibly a non-default ``n_psi``/``max_exp``)
    without touching the registry.
    """
    if not 2 <= bits <= 8:
        raise ValueError(f"PSI weight width must be in [2, 8] bits, got {bits}")
    n_psi = DEFAULT_N_PSI[bits] if n_psi is None else n_psi
    max_exp = bits - 1 if max_exp is None else max_exp
    w_min, w_max = -(1 << (bits - 1)), (1 << (bits - 1)) - 1
    vals = _value_table(bits, n_psi, max_exp)
    w = np.arange(w_min, w_max + 1)
    rel = np.abs(vals - w) / np.maximum(np.abs(w), 1)
    return PsiFormat(bits=bits, n_psi=n_psi, max_exp=max_exp,
                     w_min=w_min, w_max=w_max,
                     exact=bool(np.array_equal(vals, w)),
                     worst_case_rel_error=float(rel.max()))


def register_format(bits: int, n_psi: Optional[int] = None,
                    max_exp: Optional[int] = None) -> PsiFormat:
    """Register (or re-register) the PSI format for a weight width."""
    fmt = make_format(bits, n_psi, max_exp)
    _REGISTRY[bits] = fmt
    return fmt


def get_format(spec: FormatLike) -> PsiFormat:
    """Look a format up by bits (5), name ("psi5"), or pass one through."""
    if isinstance(spec, PsiFormat):
        return spec
    if isinstance(spec, str):
        if not spec.startswith("psi"):
            raise ValueError(f"unknown PSI format name {spec!r}")
        spec = int(spec[3:])
    if spec not in _REGISTRY:
        raise ValueError(
            f"no PSI format registered for {spec} bits "
            f"(registered: {sorted(_REGISTRY)})")
    return _REGISTRY[spec]


def registered_bits() -> Tuple[int, ...]:
    return tuple(sorted(_REGISTRY))


# ---------------------------------------------------------------------------
# Decomposition tables (exact integer bookkeeping, numpy, built lazily).
# ---------------------------------------------------------------------------
@functools.lru_cache(maxsize=None)
def _decomposition_table(bits: int, n_psi: int, max_exp: int) -> np.ndarray:
    """For every integer in the INT<bits> range, the best <= n_psi-term signed
    power-of-two decomposition (minimum absolute error; ties broken toward the
    smaller reconstructed magnitude, matching a truncating hardware rounder).

    Returns int16 array of shape (range_size, 2 * n_psi): [s_1, n_1, ..., s_N, n_N]
    indexed by (w - w_min).  Unused terms have s=0, n=0.
    """
    w_min, w_max = -(1 << (bits - 1)), (1 << (bits - 1)) - 1
    terms = []  # (value, sign, exp) including the zero term
    terms.append((0, 0, 0))
    for n in range(max_exp + 1):
        terms.append((1 << n, 1, n))
        terms.append((-(1 << n), -1, n))

    # Dynamic programming over number of terms: reachable[v] = decomposition
    # of v with <= k terms.  Value space is bounded by n_psi * 2^max_exp.
    vmax = n_psi * (1 << max_exp)
    reachable = {0: ()}
    for _ in range(n_psi):
        new = dict(reachable)
        for v, combo in reachable.items():
            for tv, ts, tn in terms[1:]:
                nv = v + tv
                if -vmax <= nv <= vmax and (nv not in new or len(new[nv]) > len(combo) + 1):
                    new[nv] = combo + ((ts, tn),)
        reachable = new

    table = np.zeros((w_max - w_min + 1, 2 * n_psi), dtype=np.int16)
    for w in range(w_min, w_max + 1):
        # pick reachable value closest to w; tie -> smaller |value|
        best_v, best_err = None, None
        for v in reachable:
            err = abs(v - w)
            if best_err is None or err < best_err or (
                err == best_err and abs(v) < abs(best_v)
            ):
                best_v, best_err = v, err
        combo = reachable[best_v]
        row = []
        for (s, n) in combo:
            row.extend([s, n])
        while len(row) < 2 * n_psi:
            row.extend([0, 0])
        table[w - w_min] = row
    return table


@functools.lru_cache(maxsize=None)
def _value_table(bits: int, n_psi: int, max_exp: int) -> np.ndarray:
    tab = _decomposition_table(bits, n_psi, max_exp)
    signs = tab[:, 0::2].astype(np.int64)
    exps = tab[:, 1::2].astype(np.int64)
    return np.sum(signs * (1 << exps), axis=1).astype(np.int32)


def _best_decomposition_table(bits: int, n_psi: Optional[int] = None) -> np.ndarray:
    """Registered-format decomposition table (``n_psi`` overrides the term
    budget — used by the monotone-error property tests)."""
    fmt = get_format(bits)
    return _decomposition_table(fmt.bits, n_psi or fmt.n_psi, fmt.max_exp)


def psi_value_table(bits: FormatLike, n_psi: Optional[int] = None) -> np.ndarray:
    """Reconstructed integer value for every code in the INT<bits> range.

    ``psi_value_table(5)[w + 16]`` is the integer the hardware actually
    multiplies by when the stored weight is ``w`` — equal to ``w`` everywhere
    except +-11 -> +-10 and +-13 -> +-12 (the paper's ~9 % worst case).
    """
    fmt = get_format(bits)
    return _value_table(fmt.bits, n_psi or fmt.n_psi, fmt.max_exp)


def psi_decompose_int(w: jnp.ndarray, bits: FormatLike) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Decompose integer weights into (signs, exps), each ``(n_psi,) + w.shape``.

    Mirrors the paper's Weight-decomposition block (Fig. 6): the stored integer
    weight is decoded into the per-PSI (s, n) register values fed to the SAMs.
    """
    fmt = get_format(bits)
    tab = jnp.asarray(fmt.decomposition_table())
    rows = tab[w.astype(jnp.int32) - fmt.w_min]
    signs = jnp.moveaxis(rows[..., 0::2], -1, 0).astype(jnp.int32)
    exps = jnp.moveaxis(rows[..., 1::2], -1, 0).astype(jnp.int32)
    return signs, exps


def psi_reconstruct(signs: jnp.ndarray, exps: jnp.ndarray) -> jnp.ndarray:
    """Inverse of :func:`psi_decompose_int` — sum of signed shifts.

    This is exactly what one SAM + the PSI-accumulation block compute.
    """
    return jnp.sum(signs * (1 << exps), axis=0).astype(jnp.int32)


def psi_project_int(w: jnp.ndarray, bits: FormatLike) -> jnp.ndarray:
    """Project integer weights onto the PSI-representable set (what the
    hardware effectively multiplies by)."""
    fmt = get_format(bits)
    tab = jnp.asarray(fmt.value_table())
    return tab[w.astype(jnp.int32) - fmt.w_min]


def sam_multiply(x: jnp.ndarray, signs: jnp.ndarray, exps: jnp.ndarray) -> jnp.ndarray:
    """Bit-faithful model of one SAM block (Fig. 2): mux(X, -X, 0) then barrel
    shift, one partial sub-integer per (sign, exp) pair; PSIs are then summed
    (the MOA's job).  ``x`` is the INT8 activation."""
    x = x.astype(jnp.int32)
    psis = jnp.where(signs == 0, 0, jnp.where(signs > 0, x, -x)) << exps
    return jnp.sum(psis, axis=0)


def moa_sign_extension_sum(operands: jnp.ndarray, in_bits: int, out_bits: int) -> jnp.ndarray:
    """The Appendix trick: summing sign-extended two's-complement operands is
    equivalent to summing the raw low ``in_bits`` fields and adding
    ``-(num_negative) * 2^{in_bits}``.  Returns the exact sum, computed the
    hardware's way, for validation against ``operands.sum()``.
    """
    operands = operands.astype(jnp.int32)
    num_neg = jnp.sum(operands < 0, axis=0)
    low = jnp.sum(jnp.where(operands < 0, operands + (1 << in_bits), operands), axis=0)
    total = low - (num_neg << in_bits)
    # wrap to out_bits two's complement (MOA output width)
    mod = 1 << out_bits
    wrapped = ((total % mod) + mod) % mod
    return jnp.where(wrapped >= (mod >> 1), wrapped - mod, wrapped)


# ---------------------------------------------------------------------------
# QuantizedTensor: the typed serving-format weight leaf.
# ---------------------------------------------------------------------------
@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(eq=False)
class QuantizedTensor:
    """A weight tensor in PSI serving format: integer storage + per-channel
    scale + its :class:`PsiFormat` as static pytree metadata.

    Storage is one of two layouts, selected by ``packed``:

    * unpacked — ``data`` is int8 codes ``(..., K, N)``, already *projected*
      onto the PSI-representable set, so dequantization is ``codes * scale``
      — identical to what the SAM array computes (reconstruct-by-shifts,
      DESIGN.md §2);
    * packed — ``data`` is uint8 bit-planes ``(..., bits, K//8, N)``
      (exactly ``bits/8`` bytes per weight in HBM).

    Registered as a pytree node: (data, scale) are children, (fmt, packed) are
    aux — so QuantizedTensor leaves flow through jit, scan (layer stacks slice
    along the leading dim), device_put, and eval_shape unchanged, and every
    consumer dispatches on ``isinstance(leaf, QuantizedTensor)`` + ``leaf.fmt``
    instead of sniffing dict keys.
    """
    data: jnp.ndarray    # int8 codes or uint8 bit-planes (see ``packed``)
    scale: jnp.ndarray   # f32, broadcastable to the code shape
    fmt: PsiFormat
    packed: bool = False

    # ------------------------------------------------------------ properties
    @property
    def bits(self) -> int:
        return self.fmt.bits

    @property
    def codes(self) -> jnp.ndarray:
        """Int8 codes ``(..., K, N)`` — unpacks bit-planes on demand."""
        if self.packed:
            return unpack_codes(self.data, self.fmt)
        return self.data

    @property
    def shape(self) -> Tuple[int, ...]:
        """Logical (dense-weight) shape."""
        if self.packed:
            *lead, _, kb, n = self.data.shape
            return (*lead, kb * 8, n)
        return tuple(self.data.shape)

    @property
    def ndim(self) -> int:
        return len(self.shape)

    # ------------------------------------------------------------ conversions
    def dequantize(self, dtype=jnp.bfloat16) -> jnp.ndarray:
        """The one shared dequantization: codes * scale, cast to ``dtype``."""
        return (self.codes.astype(jnp.float32) * self.scale).astype(dtype)

    def gather_rows(self, ids: jnp.ndarray, dtype=jnp.bfloat16) -> jnp.ndarray:
        """Dequantize only the gathered rows ``(V, D)[ids] -> (..., D)`` —
        the embedding-lookup path.  Packed tables unpack per gathered row
        (bit ``ids % 8`` of byte ``ids // 8`` in each plane) instead of
        expanding the whole table."""
        if self.packed:
            rows = unpack_rows(self.data, ids, self.fmt)
        else:
            rows = self.data[ids]
        return (rows.astype(jnp.float32) * self.scale[ids]).astype(dtype)

    def pack(self) -> "QuantizedTensor":
        """Bit-plane-packed copy (sub-byte formats only; no-op when packed)."""
        if self.packed:
            return self
        return QuantizedTensor(pack_codes(self.data, self.fmt), self.scale,
                               self.fmt, packed=True)

    def draft_view(self, bits: FormatLike) -> "QuantizedTensor":
        """A narrower-width view of the same weights — the self-speculative
        draft model (DESIGN.md §"Self-speculative decoding").

        The view is derived from the stored codes alone (no float checkpoint
        round-trip): codes rescale from the source grid to the draft grid
        (``round(c * qmax_d / qmax_s)``, clipped, PSI-projected) and the
        per-channel scale absorbs the grid ratio (``scale * qmax_s/qmax_d``).
        Because symmetric quantization puts the per-channel max |code| exactly
        at ``qmax_s``, this equals ``quantize_weights(self.dequantize(f32),
        bits)`` code-for-code: the rounding boundaries sit at half-integers of
        the draft grid — never exact ties, since both qmax values are odd —
        with granularity ``1/(2*qmax_s)``, far above f32 rounding error.  The
        invariant is property-tested in tests/test_psi.py.

        Packing is preserved: a packed source yields a packed draft (the
        draft planes are the subset-*sized* artifact the bit-plane layout
        promises — ``bits/8`` bytes per weight, no second checkpoint).
        """
        dfmt = get_format(bits)
        if dfmt.bits > self.fmt.bits:
            raise ValueError(
                f"draft_view narrows only: {self.fmt.name} -> {dfmt.name}")
        if dfmt.bits == self.fmt.bits:
            return self
        ratio = dfmt.qmax / self.fmt.qmax
        c = jnp.clip(jnp.round(self.codes.astype(jnp.float32) * ratio),
                     dfmt.w_min, dfmt.w_max).astype(jnp.int32)
        c = psi_project_int(c, dfmt)
        scale = (self.scale.astype(jnp.float32)
                 * (self.fmt.qmax / dfmt.qmax)).astype(jnp.float32)
        out = QuantizedTensor(c.astype(jnp.int8), scale, dfmt)
        return out.pack() if self.packed else out

    def unpack(self) -> "QuantizedTensor":
        if not self.packed:
            return self
        return QuantizedTensor(self.codes, self.scale, self.fmt, packed=False)

    # ---------------------------------------------------------------- pytree
    def tree_flatten(self):
        return (self.data, self.scale), (self.fmt, self.packed)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], aux[0], aux[1])


# ---------------------------------------------------------------------------
# Float-weight quantization (per-channel symmetric) + QAT straight-through.
# ---------------------------------------------------------------------------
def compute_scale(w: jnp.ndarray, bits: FormatLike, axis) -> jnp.ndarray:
    """Symmetric per-channel scale: max|w| along ``axis`` maps to qmax."""
    amax = jnp.max(jnp.abs(w), axis=axis, keepdims=True)
    return jnp.maximum(amax, 1e-8) / get_format(bits).qmax


def quantize_weights(w: jnp.ndarray, bits: FormatLike, axis=None) -> QuantizedTensor:
    """Quantize float weights to PSI format.

    ``axis`` is the reduction axis/axes for the per-channel scale (None = per
    tensor).  The integer grid point is projected onto the PSI set, so the
    stored code is bit-identical to what the TMA hardware would compute with.
    """
    fmt = get_format(bits)
    scale = compute_scale(w, fmt, axis)
    q = jnp.clip(jnp.round(w / scale), fmt.w_min, fmt.w_max).astype(jnp.int32)
    q = psi_project_int(q, fmt)
    return QuantizedTensor(q.astype(jnp.int8), scale.astype(jnp.float32), fmt)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def fake_quant_ste(w: jnp.ndarray, bits: int, axis=None) -> jnp.ndarray:
    """Quantize-dequantize with a straight-through gradient — the QAT op used
    to reproduce the paper's "trained with the proposed quantization"."""
    return quantize_weights(w, bits, axis).dequantize(w.dtype)


def _fq_fwd(w, bits, axis):
    return fake_quant_ste(w, bits, axis), None


def _fq_bwd(bits, axis, _res, g):
    return (g,)


fake_quant_ste.defvjp(_fq_fwd, _fq_bwd)


def quantize_activations_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-tensor INT8 activation quantization (paper §I: 8-bit
    activations).  Used by the bit-faithful reference path."""
    amax = jnp.maximum(jnp.max(jnp.abs(x)), 1e-8)
    scale = amax / 127.0
    q = jnp.clip(jnp.round(x / scale), -128, 127).astype(jnp.int8)
    return q, scale


# ---------------------------------------------------------------------------
# Sub-byte packing: INT<bits> codes as ``bits`` bit-planes (exactly bits/8
# bytes per weight in HBM), for every sub-byte width in the registry.
# ---------------------------------------------------------------------------
def pack_codes(codes: jnp.ndarray, fmt: FormatLike) -> jnp.ndarray:
    """Pack INT<bits> codes (..., K, N) -> uint8 bit-planes (..., bits, K//8, N).

    Bit ``b`` of weight ``codes[..., i*8 + j, n] + 2^(bits-1)`` (offset-binary)
    is stored at bit ``j`` of ``packed[..., b, i, n]``.  K must be divisible
    by 8.  Exactly bits/8 bytes per weight — the HBM footprint the psi_matmul
    kernel reads.
    """
    fmt = get_format(fmt)
    if not fmt.sub_byte:
        raise ValueError(f"bit-plane packing is for sub-byte widths, "
                         f"got {fmt.bits} bits")
    *lead, K, N = codes.shape
    if K % 8:
        raise ValueError(f"K={K} must be divisible by 8 for bit-plane packing")
    offs = (codes.astype(jnp.int32) + fmt.offset).astype(jnp.uint8)
    offs = offs.reshape(*lead, K // 8, 8, N)
    lane = jnp.arange(8, dtype=jnp.uint8).reshape(8, 1)
    planes = []
    for b in range(fmt.bits):
        bit = (offs >> b) & 1                      # (..., K//8, 8, N)
        plane = jnp.sum(bit.astype(jnp.uint32) << lane.astype(jnp.uint32), axis=-2)
        planes.append(plane.astype(jnp.uint8))     # (..., K//8, N)
    return jnp.stack(planes, axis=-3)              # (..., bits, K//8, N)


def unpack_codes(packed: jnp.ndarray, fmt: FormatLike) -> jnp.ndarray:
    """Inverse of :func:`pack_codes`: (..., bits, K//8, N) uint8 -> (..., K, N)
    int8.

    The reconstruction is a literal sum-of-shifts (``bit << b``) — the software
    mirror of the SAM barrel shifters.
    """
    fmt = get_format(fmt)
    *lead, nbits, Kb, N = packed.shape
    assert nbits == fmt.bits, (packed.shape, fmt)
    lane = jnp.arange(8, dtype=jnp.uint8).reshape(1, 8, 1)
    val = jnp.zeros((*lead, Kb, 8, N), dtype=jnp.int32)
    for b in range(fmt.bits):
        plane = packed[..., b, :, :][..., :, None, :]          # (..., K//8, 1, N)
        bit = (plane >> lane) & jnp.uint8(1)
        val = val + (bit.astype(jnp.int32) << b)
    codes = val.reshape(*lead, Kb * 8, N) - fmt.offset
    return codes.astype(jnp.int8)


def unpack_rows(packed: jnp.ndarray, rows: jnp.ndarray,
                fmt: FormatLike) -> jnp.ndarray:
    """Unpack only the selected logical rows of a packed (bits, V//8, D)
    table: row ``i`` is bit ``i % 8`` of byte ``i // 8`` in each plane.
    Returns int8 codes of shape ``rows.shape + (D,)`` — the gather-shaped
    counterpart of :func:`unpack_codes` used by embedding lookups."""
    fmt = get_format(fmt)
    if packed.ndim != 3:
        raise ValueError(
            f"unpack_rows expects an unstacked (bits, V//8, D) table, got "
            f"shape {packed.shape}; slice leading stack dims first")
    rows = rows.astype(jnp.int32)
    byte, bit = rows // 8, rows % 8
    val = jnp.zeros(rows.shape + (packed.shape[-1],), jnp.int32)
    for b in range(fmt.bits):
        plane = packed[b][byte]                    # rows.shape + (D,)
        val = val + (((plane.astype(jnp.int32) >> bit[..., None]) & 1) << b)
    return (val - fmt.offset).astype(jnp.int8)


def pack_int5(codes: jnp.ndarray) -> jnp.ndarray:
    """INT5 instance of :func:`pack_codes` (0.625 bytes/weight)."""
    return pack_codes(codes, 5)


def unpack_int5(packed: jnp.ndarray) -> jnp.ndarray:
    """INT5 instance of :func:`unpack_codes`."""
    return unpack_codes(packed, 5)


def packed_bytes_per_weight(bits: FormatLike) -> float:
    """HBM bytes per weight in serving format (the roofline 'memory' input)."""
    return get_format(bits).bytes_per_weight(packed=True)


# ---------------------------------------------------------------------------
# Default registry: every width the paper's "scalable integer weights less
# than 1-byte" covers.  INT5/INT8 are the paper's Table-I points; the rest
# open the sub-5-bit HBM-traffic frontier.
# ---------------------------------------------------------------------------
for _bits in sorted(DEFAULT_N_PSI):
    register_format(_bits)
del _bits
