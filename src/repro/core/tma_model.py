"""Cycle-approximate performance / energy / SRAM-traffic model of the TMA
accelerator (paper §II-B/C, §III, §IV).

The model reproduces, from first principles of the published dataflow:

* Table II  — peak throughput (576/288 GMACS), AlexNet frame rate @200 MHz.
* Table III — power (237 mW @250 MHz, 65 nm, 1.0 V) and TMACs/W.
* Fig. 8    — per-layer AlexNet processing time vs Eyeriss / DSIP (batch 4).
* Fig. 9    — Psum SRAM-access reduction vs Eyeriss.

Dataflow facts encoded below (all from the paper):
- NE = 9 SAMs + MOA18 → one 3x3 patch / input-shift; 4x4x16 NE array = 2,304
  parallel MACs (a 12x12x16 SAM array).
- Filter-size configuration (Fig. 7):
    R,S <= 3  -> 4 filters/pass,  64 channels/pass (Fig. 5, four 3x3x64)
    R,S <= 6  -> 2 filters/pass,  32 channels/pass (Case 1, two 5x5x32)
    R,S <= 12 -> 1 filter/pass,   16 channels/pass (Case 2, one 11x11x16)
    FC        -> 2,304-element dot product per 12 input-shifts (Case 3)
- Inputs shift horizontally one column per cycle; a full output row costs W_in
  input-shifts (FIFO feedback reuses rows, so no vertical reload).
- Multi-PSI accumulation (§IV-A): INT8 weights (4 PSIs = 2 pair-passes) add one
  accumulation cycle per output: stride-1 conv => ~2x cycles of INT5;
  stride-4 Conv1 => ~1.25x (paper's numbers, both reproduced here).
  Horizontal stride is NOT implemented in the hardware (paper §IV-A), so the
  horizontal sweep always visits every input column.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Sequence

# --------------------------------------------------------------------------
# Hardware constants (Table II / Table III).
# --------------------------------------------------------------------------
NE_COLS, NE_ROWS, NE_DEPTH, SAMS_PER_NE = 4, 4, 16, 9
MACS_PARALLEL = NE_COLS * NE_ROWS * NE_DEPTH * SAMS_PER_NE  # 2,304
SRAM_BYTES = 4 * 2 ** 20                 # 4 MB
FIFO_BYTES = 224                          # per FIFO; 12 x 16 FIFOs
N_FIFOS = 12 * 16
FPGA_FREQ_HZ = 200e6                      # Table II operating point
ASIC_FREQ_HZ = 250e6                      # Table III simulated point
ASIC_POWER_W = 0.237                      # simulated @250 MHz, 65 nm, 1.0 V
GATE_COUNT = 294_000

# PSI pair-passes per weight bit-width (2 PSIs per pass).
ACC_PASSES = {5: 1, 8: 2}


@dataclasses.dataclass(frozen=True)
class ConvLayer:
    name: str
    K: int          # output channels (total, across groups)
    C: int          # input channels (total)
    R: int          # filter height
    S: int          # filter width
    H_in: int       # padded input height
    W_in: int       # padded input width
    stride: int
    groups: int = 1

    @property
    def H_out(self) -> int:
        return (self.H_in - self.R) // self.stride + 1

    @property
    def W_out(self) -> int:
        return (self.W_in - self.S) // self.stride + 1

    @property
    def macs(self) -> int:
        return (self.K * (self.C // self.groups) * self.R * self.S
                * self.H_out * self.W_out)

    @property
    def outputs(self) -> int:
        return self.K * self.H_out * self.W_out


@dataclasses.dataclass(frozen=True)
class FCLayer:
    name: str
    In: int
    Out: int

    @property
    def macs(self) -> int:
        return self.In * self.Out


def alexnet_layers() -> List:
    """AlexNet (Krizhevsky 2012, two-tower/grouped variant — the one Eyeriss
    and DSIP benchmark).  Padded input sizes."""
    return [
        ConvLayer("conv1", 96, 3, 11, 11, 227, 227, 4),
        ConvLayer("conv2", 256, 96, 5, 5, 31, 31, 1, groups=2),
        ConvLayer("conv3", 384, 256, 3, 3, 15, 15, 1),
        ConvLayer("conv4", 384, 384, 3, 3, 15, 15, 1, groups=2),
        ConvLayer("conv5", 256, 384, 3, 3, 15, 15, 1, groups=2),
        FCLayer("fc6", 9216, 4096),
        FCLayer("fc7", 4096, 4096),
        FCLayer("fc8", 4096, 1000),
    ]


def lenet5_layers() -> List:
    return [
        ConvLayer("conv1", 6, 1, 5, 5, 32, 32, 1),
        ConvLayer("conv2", 16, 6, 5, 5, 14, 14, 1),
        FCLayer("fc3", 400, 120),
        FCLayer("fc4", 120, 84),
        FCLayer("fc5", 84, 10),
    ]


# --------------------------------------------------------------------------
# Cycle model.
# --------------------------------------------------------------------------
def _conv_config(R: int, S: int):
    """Filter-size configuration (Fig. 7): (filters/pass, channels/pass,
    psums delivered to SRAM per input-shift)."""
    if R <= 3 and S <= 3:
        return 4, 64, 4
    if R <= 6 and S <= 6:
        return 2, 32, 2
    if R <= 12 and S <= 12:
        return 1, 16, 1
    raise ValueError(f"filter {R}x{S} exceeds the 12x12 SAM array")


def conv_cycles(layer: ConvLayer, weight_bits: int) -> int:
    f_pp, d_pp, _ = _conv_config(layer.R, layer.S)
    n_acc = ACC_PASSES[weight_bits]
    cg = layer.C // layer.groups
    kg = layer.K // layer.groups
    passes = layer.groups * math.ceil(kg / f_pp) * math.ceil(cg / d_pp)
    # One horizontal sweep per output row: W_in input-shifts, plus one extra
    # accumulation cycle per produced output column for each extra PSI pass.
    shifts_per_row = layer.W_in + (n_acc - 1) * layer.W_out
    return passes * layer.H_out * shifts_per_row


def fc_cycles(layer: FCLayer, weight_bits: int) -> int:
    n_acc = ACC_PASSES[weight_bits]
    # Case 3: one 2,304-wide dot product per 12 input-shifts (+ extra PSI
    # accumulation cycles; paper: <10 % overhead for FC).
    groups_per_out = math.ceil(layer.In / MACS_PARALLEL)
    return layer.Out * groups_per_out * (12 + (n_acc - 1))


def layer_cycles(layer, weight_bits: int) -> int:
    if isinstance(layer, ConvLayer):
        return conv_cycles(layer, weight_bits)
    return fc_cycles(layer, weight_bits)


@dataclasses.dataclass
class LayerReport:
    name: str
    macs: int
    cycles: int
    time_s: float
    gmacs: float
    utilization: float
    psum_sram_accesses: int


def analyze_network(layers: Sequence, weight_bits: int,
                    freq_hz: float = FPGA_FREQ_HZ, batch: int = 1) -> List[LayerReport]:
    out = []
    for layer in layers:
        cyc = layer_cycles(layer, weight_bits) * batch
        t = cyc / freq_hz
        macs = layer.macs * batch
        out.append(LayerReport(
            name=layer.name, macs=macs, cycles=cyc, time_s=t,
            gmacs=macs / t / 1e9,
            utilization=macs / (cyc * MACS_PARALLEL),
            psum_sram_accesses=psum_sram_accesses_tma(layer) * batch,
        ))
    return out


def frame_rate(layers: Sequence, weight_bits: int, freq_hz: float = FPGA_FREQ_HZ) -> float:
    total = sum(layer_cycles(l, weight_bits) for l in layers)
    return freq_hz / total


def peak_throughput_gmacs(weight_bits: int, freq_hz: float = ASIC_FREQ_HZ) -> float:
    """Table II/III peak: 2,304 MACs/cycle at 1 PSI-pass; INT8 needs 2 passes."""
    return MACS_PARALLEL * freq_hz / ACC_PASSES[weight_bits] / 1e9


def power_w(freq_hz: float = ASIC_FREQ_HZ, voltage: float = 1.0) -> float:
    """Dynamic-power scaling around the paper's simulated design point
    (237 mW @ 250 MHz, 1.0 V, 65 nm): P ~ f * V^2."""
    return ASIC_POWER_W * (freq_hz / ASIC_FREQ_HZ) * voltage ** 2


def macs_per_watt(weight_bits: int, freq_hz: float = ASIC_FREQ_HZ,
                  voltage: float = 1.0) -> float:
    return peak_throughput_gmacs(weight_bits, freq_hz) * 1e9 / power_w(freq_hz, voltage)


def energy_per_frame_j(layers: Sequence, weight_bits: int,
                       freq_hz: float = ASIC_FREQ_HZ, voltage: float = 1.0) -> float:
    total_cycles = sum(layer_cycles(l, weight_bits) for l in layers)
    return total_cycles / freq_hz * power_w(freq_hz, voltage)


# --------------------------------------------------------------------------
# Psum SRAM-access model (§IV-B, Fig. 9).
# --------------------------------------------------------------------------
def psum_sram_accesses_tma(layer) -> int:
    """Stores + loads of partial sums.  A Psum is written once per
    channel-pass and read back for every pass after the first."""
    if isinstance(layer, ConvLayer):
        _, d_pp, _ = _conv_config(layer.R, layer.S)
        n_pass = math.ceil((layer.C // layer.groups) / d_pp)
    else:
        n_pass = math.ceil(layer.In / MACS_PARALLEL)
    stores = n_pass
    loads = n_pass - 1
    return layer.outputs * (stores + loads) if isinstance(layer, ConvLayer) \
        else layer.Out * (stores + loads)


def gate_count_model() -> Dict[str, float]:
    """Area model exposing the paper's two circuit-level claims.  Calibrated to
    the published total (294 K gates); the MOA saving (36 % vs 18 hierarchical
    CLAs) and the sign-extension saving (21 % of MOA area) are the paper's
    synthesis results, carried as model constants."""
    n_ne = NE_COLS * NE_ROWS * NE_DEPTH
    # Relative block weights chosen so the total matches Table II
    # (294 K gates / 2,304 MACs = ~128 gate-equivalents per MAC — the
    # headline of the multiplier-less design).
    sam_gates = 50.0            # 2 barrel shifters + 3:1 muxes + regs
    cla18_gates = 40.0          # one 18-bit hierarchical CLA
    moa18_gates = 18 * cla18_gates * (1 - 0.36)   # paper: -36 % vs 18 CLAs
    ne_gates = SAMS_PER_NE * sam_gates + moa18_gates
    array_gates = n_ne * ne_gates
    other = GATE_COUNT - array_gates   # MOA66s, FIFOs, control, decomposition
    return {
        "sam": sam_gates,
        "moa18": moa18_gates,
        "moa18_vs_18cla_saving": 0.36,
        "sign_ext_saving": 0.21,
        "ne": ne_gates,
        "array": array_gates,
        "other": other,
        "total": GATE_COUNT,
    }


# --------------------------------------------------------------------------
# SRAM / FIFO capacity checks (Table II sizing rationale).
# --------------------------------------------------------------------------
def check_fifo_capacity(layers: Sequence) -> bool:
    """Paper: FIFO = 224 B because the widest AlexNet conv input row is 224."""
    widest = max(l.W_in for l in layers if isinstance(l, ConvLayer))
    return widest - 3 <= FIFO_BYTES or widest <= 227  # conv1 rows stream, not loop


def psum_sram_requirement_bytes(layers: Sequence, psum_bytes: int = 4) -> int:
    """Largest per-layer Psum working set that must fit the 4 MB SRAM."""
    worst = 0
    for l in layers:
        n = l.outputs if isinstance(l, ConvLayer) else l.Out
        worst = max(worst, n * psum_bytes)
    return worst
