"""Analytic models of the accelerators the paper compares against
(Table III, Figs. 8-9): Eyeriss [5], ConvNet [6], DSIP [8].

The paper gives each baseline's published operating point (PE count, frequency,
power, GMACS).  For per-layer AlexNet latency (Fig. 8, batch=4) we model each
baseline as ``time = MACs / (PEs * freq * util_layer)`` with per-layer
utilization factors taken from the baselines' own publications where stated and
otherwise fitted to their published whole-network frame rates.  EXPERIMENTS.md
reports our reproduced speed-up ratios side-by-side with the paper's claimed
ones (24.6x / 41.7x Conv3, 13.9x / 14.9x FC1, ...).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Sequence

from repro.core import tma_model


@dataclasses.dataclass(frozen=True)
class BaselineAccel:
    name: str
    n_macs: int
    freq_hz: float
    power_w: float
    weight_bits: int
    act_bits: int
    gmacs_peak: float           # Table III "Throughput"
    conv_util: Dict[str, float]  # per-layer utilization (fit / published)
    fc_util: float
    psums_per_cycle: float       # Psum words to SRAM per active cycle (Fig. 9)
    fc_psums_per_cycle: float = None  # FC layers use a smaller PE slice

    def layer_time_s(self, layer, batch: int = 1) -> float:
        util = (self.conv_util.get(layer.name, 0.5)
                if isinstance(layer, tma_model.ConvLayer) else self.fc_util)
        return layer.macs * batch / (self.n_macs * self.freq_hz * util)

    def layer_cycles(self, layer, batch: int = 1) -> float:
        return self.layer_time_s(layer, batch) * self.freq_hz

    def psum_sram_accesses(self, layer, batch: int = 1) -> float:
        ppc = (self.psums_per_cycle if isinstance(layer, tma_model.ConvLayer)
               else (self.fc_psums_per_cycle or self.psums_per_cycle))
        return self.layer_cycles(layer, batch) * ppc

    def gmacs_per_watt(self) -> float:
        return self.gmacs_peak / self.power_w


# Eyeriss (ISCA'16 / JSSC'17): 168 PEs, 200-250 MHz, 278 mW, 23.1 GMACS
# (Table III row).  Row-stationary utilization is high on 3x3/5x5 conv and
# poor on FC (no input reuse); per-layer factors fitted to the JSSC AlexNet
# batch-4 report (~115 ms for the 5 conv layers).
EYERISS = BaselineAccel(
    name="Eyeriss", n_macs=168, freq_hz=200e6, power_w=0.278,
    weight_bits=16, act_bits=16, gmacs_peak=23.1,
    conv_util={"conv1": 0.75, "conv2": 0.39, "conv3": 0.484,
               "conv4": 0.46, "conv5": 0.53},
    fc_util=0.077,
    psums_per_cycle=12.0,   # paper §IV-B: "Eyeriss transmits 12 Psums"
    fc_psums_per_cycle=3.0,  # FC mapping drives a quarter of the column I/O
)

# ConvNet (Moons & Verhelst, JSSC'17): 256 MACs, 204 MHz, 274 mW, 52.2 GMACS.
CONVNET = BaselineAccel(
    name="ConvNet", n_macs=256, freq_hz=204e6, power_w=0.274,
    weight_bits=16, act_bits=16, gmacs_peak=52.2,
    conv_util={"conv1": 0.9, "conv2": 0.85, "conv3": 0.85,
               "conv4": 0.85, "conv5": 0.85},
    fc_util=0.3,
    psums_per_cycle=4.0,
)

# DSIP (Jo et al., JSSC'18): 64 MACs, 250 MHz, 88.6 mW, 30.1 GMACS.
DSIP = BaselineAccel(
    name="DSIP", n_macs=64, freq_hz=250e6, power_w=0.0886,
    weight_bits=16, act_bits=16, gmacs_peak=30.1,
    conv_util={"conv1": 0.80, "conv2": 0.75, "conv3": 0.60,
               "conv4": 0.70, "conv5": 0.70},
    fc_util=0.25,
    psums_per_cycle=4.0,
)

BASELINES = {"eyeriss": EYERISS, "convnet": CONVNET, "dsip": DSIP}


def table3_rows(freq_hz: float = tma_model.ASIC_FREQ_HZ) -> Sequence[dict]:
    """Reproduce Table III: baselines (published numbers) + this work
    (from the TMA cycle/energy model)."""
    rows = []
    for b in (EYERISS, CONVNET, DSIP):
        rows.append({
            "name": b.name, "weight_bits": b.weight_bits, "act_bits": b.act_bits,
            "n_macs": b.n_macs, "power_mw": b.power_w * 1e3,
            "freq_mhz": b.freq_hz / 1e6, "gmacs": b.gmacs_peak,
            "gmacs_per_w": b.gmacs_per_watt(),
        })
    for bits in (5, 8):
        rows.append({
            "name": f"TMA (INT{bits})", "weight_bits": bits, "act_bits": 8,
            "n_macs": tma_model.MACS_PARALLEL,
            "power_mw": tma_model.power_w(freq_hz) * 1e3,
            "freq_mhz": freq_hz / 1e6,
            "gmacs": tma_model.peak_throughput_gmacs(bits, freq_hz),
            "gmacs_per_w": tma_model.macs_per_watt(bits, freq_hz) / 1e9,
        })
    return rows
