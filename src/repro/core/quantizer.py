"""Model-level PSI quantization: walk a parameter pytree and convert matmul
weights into PSI serving format (codes + per-channel scale, optionally packed
sub-byte planes for INT5).

This is the software analogue of the paper's flow (Fig. 6): weights live in
DRAM/SRAM in compact integer form and the Weight-decomposition block expands
them on the way into the compute array.  Here the "compute array" is the
psi_matmul Pallas kernel which expands codes inside VMEM.
"""
from __future__ import annotations

import re
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core import psi

# Only leaves whose terminal name matches this include-list are quantized:
# GEMM weights and embedding tables.  Everything else (norm scales, biases —
# including biases that become 2-D when layer-stacked for scan — the mamba
# a_log dynamics matrix, depthwise conv mixers, and the MoE router, whose
# quantization flips top-k routing decisions for negligible storage gain)
# passes through in full precision.  See DESIGN.md §2.
WEIGHT_NAMES = (
    "wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down", "w_out",
    "w_in_rec", "w_in_gate", "rglru_wa", "rglru_wx",
    "in_proj", "x_proj", "dt_proj_w", "out_proj",
    "embed", "lm_head", "convk", "w",
)
_INCLUDE_RE = re.compile(r"(^|/)(%s)$" % "|".join(WEIGHT_NAMES))

DEFAULT_EXCLUDE = (
    r"a_log",        # mamba state matrix (parameterizes dynamics, not a GEMM)
    r"conv1d",       # mamba / rg-lru short conv (depthwise, tiny)
    r"norm",
    r"bias",
    r"router",       # tiny; quantizing it flips top-k routing
)

QUANT_MODES = ("none", "qat5", "qat8", "psi5", "psi8")


def _path_str(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)


def is_quantizable(path: str, leaf: Any) -> bool:
    if not hasattr(leaf, "ndim") or leaf.ndim < 2:
        return False
    if not jnp.issubdtype(leaf.dtype, jnp.floating):
        return False
    if not _INCLUDE_RE.search(path):
        return False
    return not any(re.search(p, path) for p in DEFAULT_EXCLUDE)


def _scale_axis(path: str, leaf) -> tuple:
    # Embedding tables: per-row scales (quality: each token row independent).
    if re.search(r"embed", path):
        return (leaf.ndim - 1,)
    # CNN kernels (H, W, I, O): per-output-channel over all spatial+input dims.
    if re.search(r"convk", path):
        return tuple(range(leaf.ndim - 1))
    # GEMM weights: reduce ONLY the contraction dim (second-to-last), so
    # layer-stacked (L, K, N) and per-expert (L, E, d, f) tensors keep
    # per-layer / per-expert scales with matching leading axes (scan-safe).
    return (leaf.ndim - 2,)


def quantize_param_tree(
    params: Dict,
    bits: int,
    pack: bool = False,
    exclude: Optional[tuple] = None,
) -> Dict:
    """Return a new tree where quantizable leaves become serving-format dicts.

    * ``{"codes": int8, "scale": f32}``             (bits=8, or bits=5 unpacked)
    * ``{"planes": uint8 (...,5,K//8,N), "scale"}``  (bits=5, pack=True)

    Non-quantizable leaves pass through unchanged.
    """
    exclude = DEFAULT_EXCLUDE if exclude is None else exclude

    def convert(path, leaf):
        p = _path_str(path)
        if not is_quantizable(p, leaf):
            return leaf
        q = psi.quantize_weights(leaf, bits, axis=_scale_axis(p, leaf))
        if (pack and bits == 5 and leaf.ndim >= 2
                and leaf.shape[-2] % 8 == 0 and not re.search(r"embed", p)):
            return {"planes": psi.pack_int5(q.codes), "scale": q.scale}
        return {"codes": q.codes, "scale": q.scale}

    return jax.tree_util.tree_map_with_path(convert, params)


def dequantize_leaf(leaf: Any, dtype=jnp.bfloat16):
    """Expand one serving-format leaf back to a dense float array."""
    if isinstance(leaf, dict) and "planes" in leaf:
        codes = psi.unpack_int5(leaf["planes"])
        return (codes.astype(jnp.float32) * leaf["scale"]).astype(dtype)
    if isinstance(leaf, dict) and "codes" in leaf:
        return (leaf["codes"].astype(jnp.float32) * leaf["scale"]).astype(dtype)
    return leaf


def fake_quant_param_tree(params: Dict, bits: int, exclude: Optional[tuple] = None) -> Dict:
    """QAT forward transform: quantize-dequantize every quantizable leaf with a
    straight-through gradient.  Apply inside the loss so dLoss/dw flows to the
    latent float weights (paper: networks are *trained with* the quantization).
    """
    exclude = DEFAULT_EXCLUDE if exclude is None else exclude

    def convert(path, leaf):
        p = _path_str(path)
        if not is_quantizable(p, leaf):
            return leaf
        return psi.fake_quant_ste(leaf, bits, _scale_axis(p, leaf))

    return jax.tree_util.tree_map_with_path(convert, params)


def quantized_bytes(params: Dict) -> int:
    """Total serving-format bytes (for EXPERIMENTS.md compression reporting)."""
    total = 0
    for leaf in jax.tree_util.tree_leaves(params):
        total += leaf.size * leaf.dtype.itemsize
    return total
