"""Model-level PSI quantization: walk a parameter pytree and convert matmul
weights into PSI serving format (:class:`repro.core.psi.QuantizedTensor`
leaves — integer codes + per-channel scale, optionally packed sub-byte
bit-planes).

This is the software analogue of the paper's flow (Fig. 6): weights live in
DRAM/SRAM in compact integer form and the Weight-decomposition block expands
them on the way into the compute array.  Here the "compute array" is the
psi_matmul Pallas kernel which expands codes inside VMEM.

Mixed precision is a first-class policy: ``quantize_param_tree(params,
policy={"embed": 8, "w_down": 4, "default": 5})`` assigns a registered
:class:`~repro.core.psi.PsiFormat` per terminal leaf name — the lever the
memory-bound regime rewards (per-layer bytes/weight is the HBM-traffic dial).
"""
from __future__ import annotations

import re
import warnings
from typing import Any, Dict, Mapping, Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core import psi

# Only leaves whose terminal name matches this include-list are quantized:
# GEMM weights and embedding tables.  Everything else (norm scales, biases —
# including biases that become 2-D when layer-stacked for scan — the mamba
# a_log dynamics matrix, depthwise conv mixers, and the MoE router, whose
# quantization flips top-k routing decisions for negligible storage gain)
# passes through in full precision.  See DESIGN.md §2.
WEIGHT_NAMES = (
    "wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down", "w_out",
    "w_in_rec", "w_in_gate", "rglru_wa", "rglru_wx",
    "in_proj", "x_proj", "dt_proj_w", "out_proj",
    "embed", "lm_head", "convk", "w",
)
_INCLUDE_RE = re.compile(r"(^|/)(%s)$" % "|".join(WEIGHT_NAMES))

DEFAULT_EXCLUDE = (
    r"a_log",        # mamba state matrix (parameterizes dynamics, not a GEMM)
    r"conv1d",       # mamba / rg-lru short conv (depthwise, tiny)
    r"norm",
    r"bias",
    r"router",       # tiny; quantizing it flips top-k routing
)

# A policy maps terminal leaf names (regex alternatives, matched like the
# include-list) to registered bit-widths; "default" covers the rest.  A bits
# value of 0/None leaves those weights in float.
Policy = Mapping[str, Optional[int]]


def parse_quant_mode(mode: str) -> Tuple[Optional[str], Optional[int]]:
    """"none" -> (None, None); "qatN" -> ("qat", N); "psiN" -> ("psi", N).
    N must name a registered :class:`~repro.core.psi.PsiFormat`."""
    if mode in ("", "none", None):
        return None, None
    m = re.fullmatch(r"(qat|psi)(\d+)", mode)
    if not m:
        raise ValueError(f"unknown quant mode {mode!r} "
                         f"(expected none / qatN / psiN)")
    kind, bits = m.group(1), int(m.group(2))
    psi.get_format(bits)      # raises on unregistered widths
    return kind, bits


def quant_mode_choices() -> Tuple[str, ...]:
    """Valid quant-mode strings, derived from the format registry (the
    replacement for the old hard-coded QUANT_MODES tuple)."""
    bits = psi.registered_bits()
    return (("none",) + tuple(f"qat{b}" for b in bits)
            + tuple(f"psi{b}" for b in bits))


def serving_mode_choices() -> Tuple[str, ...]:
    """Registry-derived serving-format choices for the serve/dryrun CLIs
    (QAT modes are a training concern and are excluded)."""
    return ("none",) + tuple(f"psi{b}" for b in psi.registered_bits())


def parse_policy(spec: Union[str, Policy, None]) -> Optional[Dict[str, Optional[int]]]:
    """Normalize a mixed-precision policy.

    Accepts a mapping ({"embed": 8, "default": 5}) or the CLI string form
    "embed=8,w_down=4,default=5".  Every bits value must name a registered
    format (0 means "keep float").
    """
    if spec is None:
        return None
    if isinstance(spec, str):
        out: Dict[str, Optional[int]] = {}
        for item in spec.split(","):
            item = item.strip()
            if not item:
                continue
            name, _, val = item.partition("=")
            if not _:
                raise ValueError(f"policy entry {item!r} is not name=bits")
            out[name.strip()] = int(val)
    else:
        out = dict(spec)
    for name, bits in out.items():
        if bits:
            psi.get_format(bits)
        if name == "default":
            continue
        try:
            re.compile(rf"(^|/)(?:{name})$")
        except re.error as e:
            # fail at the flag, not with a raw re.error deep inside tree_map
            raise ValueError(
                f"policy name {name!r} is not a valid leaf-name pattern "
                f"({e})") from None
    return out


def _policy_bits(path: str, policy: Optional[Dict[str, Optional[int]]],
                 default: Optional[int]) -> Optional[int]:
    """Resolve the bit-width for one leaf: first policy entry whose name
    matches the leaf's terminal path component wins, then the policy's
    "default", then the uniform ``default`` bits."""
    if policy:
        for name, bits in policy.items():
            if name == "default":
                continue
            if re.search(rf"(^|/)(?:{name})$", path):
                return bits
        if "default" in policy:
            return policy["default"]
    return default


def _path_str(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)


def is_quantizable(path: str, leaf: Any) -> bool:
    if not hasattr(leaf, "ndim") or leaf.ndim < 2:
        return False
    if not jnp.issubdtype(leaf.dtype, jnp.floating):
        return False
    if not _INCLUDE_RE.search(path):
        return False
    return not any(re.search(p, path) for p in DEFAULT_EXCLUDE)


def _scale_axis(path: str, leaf) -> tuple:
    # Embedding tables: per-row scales (quality: each token row independent).
    if re.search(r"embed", path):
        return (leaf.ndim - 1,)
    # CNN kernels (H, W, I, O): per-output-channel over all spatial+input dims.
    if re.search(r"convk", path):
        return tuple(range(leaf.ndim - 1))
    # GEMM weights: reduce ONLY the contraction dim (second-to-last), so
    # layer-stacked (L, K, N) and per-expert (L, E, d, f) tensors keep
    # per-layer / per-expert scales with matching leading axes (scan-safe).
    return (leaf.ndim - 2,)


def quantize_param_tree(
    params: Dict,
    bits: Optional[int] = None,
    pack: bool = False,
    exclude: Optional[tuple] = None,
    policy: Union[str, Policy, None] = None,
) -> Dict:
    """Return a new tree where quantizable leaves become
    :class:`~repro.core.psi.QuantizedTensor` serving leaves.

    * ``bits`` — uniform width for every quantizable leaf;
    * ``policy`` — per-layer mixed precision, e.g. ``{"embed": 8,
      "w_down": 4, "default": 5}`` (overrides ``bits`` where it matches);
    * ``pack=True`` — sub-byte leaves additionally bit-plane pack
      (``fmt.bits/8`` bytes per weight in HBM) when the contraction dim is a
      multiple of 8; embeddings stay unpacked (row-gather path).

    Non-quantizable leaves pass through unchanged.
    """
    exclude = DEFAULT_EXCLUDE if exclude is None else exclude
    policy = parse_policy(policy)
    if bits is None and not policy:
        raise ValueError("pass uniform bits= and/or a mixed-precision policy=")
    paths, qpaths = [], []

    def convert(path, leaf):
        p = _path_str(path)
        paths.append(p)
        if not is_quantizable(p, leaf):
            return leaf
        qpaths.append(p)
        leaf_bits = _policy_bits(p, policy, bits)
        if not leaf_bits:
            return leaf
        q = psi.quantize_weights(leaf, leaf_bits, axis=_scale_axis(p, leaf))
        if (pack and q.fmt.sub_byte and leaf.ndim >= 2
                and leaf.shape[-2] % 8 == 0 and not re.search(r"embed", p)):
            return q.pack()
        return q

    out = jax.tree_util.tree_map_with_path(convert, params)
    if policy:
        # A policy entry that silently has no effect is exactly the failure
        # mixed precision exists to avoid.  Two loud cases: a key matching
        # no leaf at all (typo), and a *nonzero*-bits key matching only
        # excluded/non-quantizable leaves (contradicted intent — e.g.
        # router=8 when the router is on the exclude list).  A deliberate
        # {"router": 0} keep-float entry stays quiet.
        def hit(key, pool):
            return any(re.search(rf"(^|/)(?:{key})$", p) for p in pool)

        dead = [k for k in policy if k != "default" and not hit(k, paths)]
        ineffective = [k for k in policy
                       if k != "default" and policy[k] and k not in dead
                       and not hit(k, qpaths)]
        if dead:
            warnings.warn(
                f"quantization policy entries matched no parameter leaf: "
                f"{sorted(dead)} (known weight names: {WEIGHT_NAMES})",
                stacklevel=2)
        if ineffective:
            warnings.warn(
                f"quantization policy entries match only excluded/"
                f"non-quantizable leaves and have no effect: "
                f"{sorted(ineffective)} (see DEFAULT_EXCLUDE)", stacklevel=2)
    return out


def draft_param_tree(params: Dict, draft_bits: int) -> Dict:
    """Self-speculative draft parameters: every :class:`QuantizedTensor` leaf
    wider than ``draft_bits`` is replaced by its :meth:`draft_view` (derived
    from the stored codes, no re-quantization from float); float leaves and
    leaves already at or below the draft width pass through unchanged, so the
    draft tree has the *same pytree structure* as the serving tree and reuses
    its sharding specs verbatim."""
    fmt = psi.get_format(draft_bits)

    def convert(leaf):
        if isinstance(leaf, psi.QuantizedTensor) and leaf.fmt.bits > fmt.bits:
            return leaf.draft_view(fmt)
        return leaf

    return jax.tree_util.tree_map(
        convert, params,
        is_leaf=lambda x: isinstance(x, psi.QuantizedTensor))


def dequantize(leaf: Any, dtype=jnp.bfloat16):
    """THE shared dequantize helper: expand one serving-format leaf back to a
    dense float array; non-quantized leaves pass through.  Every inline
    scale-application in the model zoo routes here (DESIGN.md §2)."""
    if isinstance(leaf, psi.QuantizedTensor):
        return leaf.dequantize(dtype)
    return leaf


# Backwards-compatible name (pre-QuantizedTensor API).
dequantize_leaf = dequantize


def fake_quant_param_tree(params: Dict, bits: int, exclude: Optional[tuple] = None) -> Dict:
    """QAT forward transform: quantize-dequantize every quantizable leaf with a
    straight-through gradient.  Apply inside the loss so dLoss/dw flows to the
    latent float weights (paper: networks are *trained with* the quantization).
    """
    exclude = DEFAULT_EXCLUDE if exclude is None else exclude

    def convert(path, leaf):
        p = _path_str(path)
        if not is_quantizable(p, leaf):
            return leaf
        return psi.fake_quant_ste(leaf, bits, _scale_axis(p, leaf))

    return jax.tree_util.tree_map_with_path(convert, params)


def quantized_bytes(params: Dict) -> int:
    """Total serving-format bytes (for EXPERIMENTS.md compression reporting).
    QuantizedTensor leaves flatten to their storage (codes or packed planes)
    plus scales, so packed sub-byte formats report their true footprint."""
    total = 0
    for leaf in jax.tree_util.tree_leaves(params):
        total += leaf.size * leaf.dtype.itemsize
    return total
