"""falcon-mamba-7b [arXiv:2410.05355; unverified] — attention-free Mamba-1 SSM.

PSI quantization applies to the in/x/dt/out projections (~97 % of params);
the selective-scan recurrence itself is elementwise (DESIGN.md §4).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="falcon-mamba-7b", family="ssm",
    n_layers=64, d_model=4096, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab_size=65024,
    rope="none",
    ssm_state=16, ssm_expand=2, ssm_conv=4, ssm_dt_rank=256,
    norm="rmsnorm",
    source="arXiv:2410.05355; unverified",
))
