"""phi3-medium-14b [arXiv:2404.14219; unverified] — dense, RoPE + SwiGLU + GQA kv=10."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="phi3-medium-14b", family="dense",
    n_layers=40, d_model=5120, n_heads=40, n_kv_heads=10,
    d_ff=17920, vocab_size=100352, head_dim=128,
    rope="rope", act="swiglu", norm="rmsnorm",
    source="arXiv:2404.14219; unverified",
))
