"""granite-34b [arXiv:2405.04324; hf] — dense code model, MQA kv=1, 88 layers.

MLP is 2-matrix GELU (gpt_bigcode lineage): with d_ff=24576 that yields
33.8B params — matching the model's name; a 3-matrix SwiGLU would be 47B.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="granite-34b", family="dense",
    n_layers=88, d_model=6144, n_heads=48, n_kv_heads=1,
    d_ff=24576, vocab_size=49152, head_dim=128,
    rope="rope", act="gelu", norm="rmsnorm",
    source="arXiv:2405.04324; hf",
))
