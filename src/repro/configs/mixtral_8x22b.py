"""mixtral-8x22b [arXiv:2401.04088; hf] — MoE 8 experts top-2, sliding-window attention."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="mixtral-8x22b", family="moe",
    n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=16384,               # per-expert intermediate size
    vocab_size=32768, head_dim=128,
    rope="rope", rope_theta=1e6,
    attn_type="swa", window=4096,     # SWA bounds decode KV -> long_500k runs
    n_experts=8, top_k=2, capacity_factor=1.25,
    act="swiglu", norm="rmsnorm",
    source="arXiv:2401.04088; hf",
))
