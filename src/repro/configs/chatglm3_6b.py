"""chatglm3-6b [arXiv:2406.12793; hf] — dense, 2d-RoPE (partial rotary), GQA kv=2."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="chatglm3-6b", family="dense",
    n_layers=28, d_model=4096, n_heads=32, n_kv_heads=2,
    d_ff=13696, vocab_size=65024, head_dim=128,
    rope="rope2d",            # RoPE applied to half the head dims (2d scheme)
    act="swiglu", norm="rmsnorm",
    source="arXiv:2406.12793; hf",
))
