"""qwen3-8b [hf:Qwen/Qwen3-8B; hf] — dense, qk-norm, GQA kv=8."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen3-8b", family="dense",
    n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=12288, vocab_size=151936, head_dim=128,
    rope="rope", rope_theta=1e6, qk_norm=True,
    act="swiglu", norm="rmsnorm",
    source="hf:Qwen/Qwen3-8B; hf",
))
