"""Architecture registry: one module per assigned architecture.

Importing this package registers all configs; use ``get_config(name)``.
"""
from repro.configs.base import (  # noqa: F401
    ModelConfig, ShapeConfig, SHAPES, get_config, list_configs,
    reduced_config, register, shape_applicable,
)

# Assigned architectures (public-literature configs; tiers in each module).
from repro.configs import chatglm3_6b        # noqa: F401
from repro.configs import qwen3_8b           # noqa: F401
from repro.configs import granite_34b        # noqa: F401
from repro.configs import phi3_medium_14b    # noqa: F401
from repro.configs import whisper_base       # noqa: F401
from repro.configs import qwen3_moe_30b_a3b  # noqa: F401
from repro.configs import mixtral_8x22b      # noqa: F401
from repro.configs import recurrentgemma_9b  # noqa: F401
from repro.configs import qwen2_vl_2b        # noqa: F401
from repro.configs import falcon_mamba_7b    # noqa: F401

ASSIGNED_ARCHS = (
    "chatglm3-6b", "qwen3-8b", "granite-34b", "phi3-medium-14b",
    "whisper-base", "qwen3-moe-30b-a3b", "mixtral-8x22b",
    "recurrentgemma-9b", "qwen2-vl-2b", "falcon-mamba-7b",
)
