"""Config system: model architecture, input shapes, quantization and
parallelism settings.  Every assigned architecture is a `ModelConfig` in its
own module under ``repro.configs``; `get_config(name)` is the registry entry
point used by ``--arch`` flags throughout the launchers.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Model architecture.
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 -> d_model // n_heads

    # --- attention ---
    attn_type: str = "full"         # full | swa (sliding window)
    window: int = 0                 # swa / local-attention window
    rope: str = "rope"              # rope | rope2d | mrope | sinusoidal | none
    rope_theta: float = 10_000.0
    qk_norm: bool = False
    norm: str = "rmsnorm"           # rmsnorm | layernorm
    act: str = "swiglu"             # swiglu | geglu | gelu

    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01

    # --- SSM (mamba1) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_dt_rank: int = 0            # 0 -> d_model // 16

    # --- hybrid (recurrentgemma): repeating block pattern ---
    block_pattern: Tuple[str, ...] = ()   # e.g. ("rec", "rec", "attn")
    d_rnn: int = 0                  # 0 -> d_model
    rglru_c: float = 8.0

    # --- encoder-decoder (whisper backbone; frontend stubbed) ---
    n_enc_layers: int = 0
    enc_frames: int = 1500

    # --- VLM (qwen2-vl backbone; vision frontend stubbed) ---
    vision_patches: int = 0

    # --- numerics / technique ---
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    quant_mode: str = "none"        # none | qat5 | qat8 | psi5 | psi8
    dtype: str = "bfloat16"
    remat: bool = True
    scan_layers: bool = True        # stack layers + lax.scan (compile speed)

    # --- activation layout (set by the launcher; Megatron-style sequence
    # sharding of the residual stream between blocks — the scan-saved
    # activations would otherwise be (L, B, S, d) replicated on "model") ---
    act_seq_axis: str = ""                 # e.g. "model"
    act_batch_axes: Tuple[str, ...] = ()   # e.g. ("data",) / ("pod", "data")
    moe_expert_axis: str = ""              # "model" when E % mesh_model == 0

    # --- beyond-paper: KV-cache compression (extends the paper's weight-
    # compression insight to the tensor that actually dominates decode HBM
    # traffic at large batch; see EXPERIMENTS.md §Perf) ---
    kv_quant: str = ""                     # "" | "int8"

    # --- decode-cache layout (DESIGN.md §3): "paged" stores attention KV in
    # a block pool indexed through per-slot block tables (admission bounded
    # by actual tokens, not worst-case sequence); "dense" is the classic
    # per-slot slab and stays required for recurrent/SSM state, SWA rings,
    # and encoder-decoder caches.  "auto" resolves per family. ---
    cache_layout: str = "auto"             # auto | dense | paged
    cache_block_size: int = 16             # positions per paged block

    # --- shared-prefix block reuse (DESIGN.md §3 "Prefix cache"): serve
    # identical block-aligned prompt prefixes out of ref-counted pool
    # blocks instead of re-prefilling them.  Requires the paged layout and
    # plain-RoPE positions (set per serve via --prefix-cache). ---
    prefix_cache: bool = False

    # --- citation bookkeeping (verification tier from the assignment) ---
    source: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def resolved_dt_rank(self) -> int:
        return self.ssm_dt_rank or max(self.d_model // 16, 1)

    @property
    def resolved_d_rnn(self) -> int:
        return self.d_rnn or self.d_model

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def paged_capable(self) -> bool:
        """True when every decode-cache leaf is full-attention KV — the only
        state a block pool can hold.  Recurrent/SSM state is fixed-size (no
        paging to do), SWA rings wrap past ``max_seq`` (a bounded block
        table cannot), and whisper's decoder carries ``enc_out``."""
        return (self.family in ("dense", "moe", "vlm")
                and self.attn_type == "full")

    @property
    def resolved_cache_layout(self) -> str:
        """``cache_layout`` with "auto" resolved: paged for attention
        families, dense where the state is not pageable (DESIGN.md §3)."""
        if self.cache_layout == "auto":
            return "paged" if self.paged_capable else "dense"
        if self.cache_layout not in ("dense", "paged"):
            raise ValueError(f"unknown cache_layout {self.cache_layout!r} "
                             f"(want auto | dense | paged)")
        if self.cache_layout == "paged" and not self.paged_capable:
            raise ValueError(
                f"{self.name or self.family}: cache_layout=paged requires a "
                f"pure full-attention stack (family {self.family!r}, "
                f"attn_type {self.attn_type!r} must use dense)")
        return self.cache_layout

    @property
    def prefix_cache_enabled(self) -> bool:
        """``prefix_cache`` validated against the resolved layout: block
        reuse shares PAGED pool blocks (a dense slab has no blocks to
        share) and replays absolute RoPE positions (mrope/2-D/sinusoidal
        position schemes embed positions the suffix prefill cannot
        reproduce from a scalar ``pos0``)."""
        if not self.prefix_cache:
            return False
        if self.resolved_cache_layout != "paged":
            raise ValueError(
                f"{self.name or self.family}: prefix_cache requires the "
                f"paged cache layout (resolved "
                f"{self.resolved_cache_layout!r})")
        if self.rope != "rope":
            raise ValueError(
                f"{self.name or self.family}: prefix_cache requires plain "
                f"RoPE positions, got rope={self.rope!r}")
        return True

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k: bounded decode state (SSM / hybrid / SWA)."""
        return (self.family in ("ssm", "hybrid")
                or (self.attn_type == "swa" and self.window > 0))

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, L = self.d_model, self.n_layers
        hd = self.resolved_head_dim
        q = self.n_heads * hd
        kv = self.n_kv_heads * hd
        n = self.vocab_size * d                       # embed
        if not self.tie_embeddings:
            n += d * self.vocab_size                  # lm head
        def attn_params():
            return d * q + 2 * d * kv + q * d
        def mlp_params(ff):
            if self.act in ("swiglu", "geglu"):
                return 3 * d * ff
            return 2 * d * ff
        if self.family == "ssm":
            di, r, s = self.d_inner, self.resolved_dt_rank, self.ssm_state
            per = (d * 2 * di + di * self.ssm_conv + di * (r + 2 * s)
                   + r * di + di * s + di + di * d)
            n += L * per
        elif self.family == "hybrid":
            pat = self.block_pattern or ("rec",)
            dr = self.resolved_d_rnn
            rec = 2 * d * dr + dr * self.ssm_conv + 2 * dr * dr + dr * d
            for i in range(L):
                kind = pat[i % len(pat)]
                n += (attn_params() if kind == "attn" else rec) + mlp_params(self.d_ff)
        elif self.family == "moe":
            per = attn_params() + d * self.n_experts  # router
            per += self.n_experts * 3 * d * self.d_ff
            n += L * per
        elif self.family == "encdec":
            enc = self.n_enc_layers * (attn_params() + mlp_params(self.d_ff))
            dec = L * (2 * attn_params() + mlp_params(self.d_ff))
            n += enc + dec
        else:                                          # dense / vlm
            n += L * (attn_params() + mlp_params(self.d_ff))
        return n

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k of n_experts)."""
        if self.family != "moe":
            return self.param_count()
        full = self.param_count()
        expert = self.n_layers * self.n_experts * 3 * self.d_model * self.d_ff
        active = expert * self.top_k / self.n_experts
        return int(full - expert + active)


# ---------------------------------------------------------------------------
# Input shapes (assigned to every LM-family architecture).
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                       # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """40-cell applicability matrix (skips recorded in DESIGN.md §4)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("pure full-attention arch: 524k dense-KV decode is the "
                       "quadratic regime long_500k excludes (DESIGN.md §4)")
    return True, ""


# ---------------------------------------------------------------------------
# Registry.
# ---------------------------------------------------------------------------
_REGISTRY = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str, **overrides) -> ModelConfig:
    import repro.configs  # noqa: F401  (populates registry)
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    cfg = _REGISTRY[name]
    return dataclasses.replace(cfg, **overrides) if overrides else cfg


def list_configs():
    import repro.configs  # noqa: F401
    return sorted(_REGISTRY)


def reduced_config(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests: identical code paths,
    laptop-scale shapes (widths multiples of 8 for INT5 packing).

    dtype defaults to float32 here: the CPU backend's DotThunk lacks some
    bf16 dot configurations that fused scan bodies produce; the TPU-target
    bf16 path is exercised by the dry-run (lower+compile, no execution).
    capacity_factor is raised so MoE token dropping cannot make the
    decode-vs-forward consistency checks diverge at toy batch sizes."""
    small = dict(
        dtype="float32",
        capacity_factor=max(cfg.capacity_factor, 8.0) if cfg.n_experts else cfg.capacity_factor,
        n_layers=max(2, len(cfg.block_pattern) or 2),
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads else 0,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        window=min(cfg.window, 32) if cfg.window else 0,
        n_experts=min(cfg.n_experts, 8) if cfg.n_experts else 0,
        top_k=min(cfg.top_k, 2) if cfg.top_k else 0,
        ssm_state=min(cfg.ssm_state, 8) if cfg.ssm_state else 0,
        ssm_dt_rank=8 if cfg.family == "ssm" else 0,
        n_enc_layers=2 if cfg.n_enc_layers else 0,
        enc_frames=16 if cfg.n_enc_layers else 1500,
        vision_patches=min(cfg.vision_patches, 8) if cfg.vision_patches else 0,
        d_rnn=64 if cfg.family == "hybrid" else 0,
        scan_layers=cfg.scan_layers,
    )
    small.update(overrides)
    return dataclasses.replace(cfg, **small)
