"""whisper-base [arXiv:2212.04356; unverified] — encoder-decoder audio backbone.

The conv frontend is a STUB per the assignment: ``input_specs()`` feeds
precomputed frame embeddings (B, enc_frames, d_model); the transformer
backbone (6L enc + 6L dec, MHA 8 heads, GELU, LayerNorm, sinusoidal pos)
is implemented in full.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="whisper-base", family="encdec",
    n_layers=6, n_enc_layers=6, enc_frames=1500,
    d_model=512, n_heads=8, n_kv_heads=8,
    d_ff=2048, vocab_size=51865, head_dim=64,
    rope="sinusoidal", act="gelu", norm="layernorm",
    tie_embeddings=True,
    source="arXiv:2212.04356; unverified",
))
