"""qwen2-vl-2b [arXiv:2409.12191; hf] — VLM backbone with M-RoPE.

Vision frontend is a STUB per the assignment: ``input_specs()`` provides
precomputed patch embeddings (B, vision_patches, d_model) that occupy the
leading positions of the sequence; M-RoPE position ids (3, B, S) for the
temporal/height/width sections come with the inputs.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen2-vl-2b", family="vlm",
    n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2,
    d_ff=8960, vocab_size=151936, head_dim=128,
    rope="mrope", rope_theta=1e6,
    vision_patches=256,
    act="swiglu", norm="rmsnorm",
    tie_embeddings=True,
    source="arXiv:2409.12191; hf",
))
