"""qwen3-moe-30b-a3b [hf:Qwen/Qwen3-30B-A3B; hf] — MoE 128 experts top-8, GQA kv=4."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4,
    d_ff=768,                 # per-expert intermediate size
    vocab_size=151936, head_dim=128,
    rope="rope", rope_theta=1e6, qk_norm=True,
    n_experts=128, top_k=8, capacity_factor=1.25,
    act="swiglu", norm="rmsnorm",
    source="hf:Qwen/Qwen3-30B-A3B; hf",
))
