"""recurrentgemma-9b [arXiv:2402.19427; unverified] — Griffin hybrid:
RG-LRU recurrent blocks + local attention in a (rec, rec, attn) pattern,
MQA kv=1, local window 2048."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="recurrentgemma-9b", family="hybrid",
    n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1,
    d_ff=12288, vocab_size=256000, head_dim=256,
    block_pattern=("rec", "rec", "attn"),
    d_rnn=4096, rglru_c=8.0,
    attn_type="swa", window=2048,
    rope="rope", act="geglu", norm="rmsnorm",
    tie_embeddings=True,
    source="arXiv:2402.19427; unverified",
))
