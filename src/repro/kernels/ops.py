"""Jit'd dispatch wrappers around the Pallas kernels.

The serving weight arrives as a :class:`~repro.core.psi.QuantizedTensor`;
dispatch is typed — storage layout (``qt.packed``) picks the kernel body and
``qt.fmt.bits`` parameterizes it — with explicit backend routing (no silent
fall-through):

  * ``tpu``          -> the Pallas kernel (compressed weights in HBM,
                        VMEM dequantization);
  * ``gpu`` / ``cuda`` / ``rocm``
                     -> the dequantize-then-einsum fast path in
                        ``repro.kernels.ref`` (tensor-core-eligible dense
                        dot; the bit-plane loop has no Mosaic pipeline to
                        win on a GPU);
  * anything else (``cpu``) -> the pure-jnp oracle — identical semantics
                        (tests assert allclose between the interpret-mode
                        kernel and the oracle).

Set ``REPRO_FORCE_INTERPRET=1`` to route through
``pallas_call(interpret=True)`` on CPU (used by kernel tests).

Decode-shaped dispatch (DESIGN.md §2): the M-tile follows the actual row
count (``psi_matmul.pick_bm``), so a decode step over <=16 slots stops
padding M up to the 128-row MXU tile (8-16x fewer padded MACs per GEMV;
tracked by ``benchmarks/kernel_bench.py``).

:func:`paged_decode_attention` applies the same contract to the paged
decode read side (DESIGN.md §3 "Paged-decode kernel"): tpu -> the fused
flash-decode Pallas kernel in ``repro.kernels.paged_attention`` (no dense
gathered temporary), gpu -> its dense-gather fast path, cpu -> its
pure-XLA oracle; ``REPRO_PAGED_ATTN`` force-overrides the route by name.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.core import psi
from repro.kernels import paged_attention as _pa
from repro.kernels import psi_matmul as _pk
from repro.kernels import ref as _ref

_GPU_BACKENDS = ("gpu", "cuda", "rocm")
_PAGED_ROUTES = ("pallas", "gather", "ref", "interpret")


def _backend() -> str:
    return jax.default_backend()


def _use_pallas() -> bool:
    return _backend() == "tpu"


def _use_gpu_fast_path() -> bool:
    return _backend() in _GPU_BACKENDS


def _force_interpret() -> bool:
    return os.environ.get("REPRO_FORCE_INTERPRET", "0") == "1"


def psi_matmul_2d(x2d: jnp.ndarray, qt: psi.QuantizedTensor) -> jnp.ndarray:
    """(M, K) x QuantizedTensor weight -> (M, N)."""
    scale = qt.scale.reshape(-1)
    bm = _pk.pick_bm(x2d.shape[0], x2d.dtype)
    if qt.packed:
        bits = qt.fmt.bits
        if _use_pallas():
            return _pk.psi_matmul_packed(x2d, qt.data, scale, bits=bits,
                                         bm=bm)
        if _use_gpu_fast_path():
            return _ref.psi_matmul_packed_dequant(x2d, qt.data, scale, bits)
        if _force_interpret():
            return _pk.psi_matmul_packed(x2d, qt.data, scale, bits=bits,
                                         bm=bm, interpret=True)
        return _ref.psi_matmul_packed_ref(x2d, qt.data, scale, bits)
    if _use_pallas():
        return _pk.psi_matmul_codes(x2d, qt.data, scale, bm=bm)
    if _use_gpu_fast_path():
        return _ref.psi_matmul_codes_dequant(x2d, qt.data, scale)
    if _force_interpret():
        return _pk.psi_matmul_codes(x2d, qt.data, scale, bm=bm,
                                    interpret=True)
    return _ref.psi_matmul_codes_ref(x2d, qt.data, scale)


def psi_matmul(x: jnp.ndarray, qt: psi.QuantizedTensor) -> jnp.ndarray:
    """(..., K) x QuantizedTensor weight -> (..., N); flattens leading dims."""
    lead = x.shape[:-1]
    K = x.shape[-1]
    y = psi_matmul_2d(x.reshape(-1, K), qt)
    return y.reshape(*lead, y.shape[-1])


def paged_attn_route() -> str:
    """Resolved backend route for the paged-decode attention read side.

    Same explicit contract as :func:`psi_matmul_2d` — tpu -> the Pallas
    flash-decode kernel, gpu -> the dense-gather fast path, cpu -> the
    pure-XLA oracle (the token-identity reference); ``REPRO_FORCE_INTERPRET``
    routes through ``pallas_call(interpret=True)``.  ``REPRO_PAGED_ATTN``
    overrides the route by name (``pallas`` / ``gather`` / ``ref`` /
    ``interpret``); an unknown name fails loudly rather than silently
    falling through."""
    env = os.environ.get("REPRO_PAGED_ATTN", "auto")
    if env != "auto":
        if env not in _PAGED_ROUTES:
            raise ValueError(
                f"REPRO_PAGED_ATTN={env!r}: expected one of "
                f"{('auto',) + _PAGED_ROUTES}")
        return env
    if _use_pallas():
        return "pallas"
    if _use_gpu_fast_path():
        return "gather"
    if _force_interpret():
        return "interpret"
    return "ref"


def paged_decode_attention(q, k_pool, v_pool, block_tables, pos,
                           k_scale=None, v_scale=None):
    """Routed paged-decode attention read side (no gathered temporary on
    TPU; DESIGN.md §3 "Paged-decode kernel").

    q (B, Hq, D); k/v pools (N, bs, Hkv, D) (int8 codes plus per-entry
    ``k_scale``/``v_scale`` (N, bs, Hkv, 1) f32 under ``kv_quant="int8"``);
    block_tables (B, n_bt) int32 (−1 = unallocated); pos (B,) absolute
    query positions.  Returns (B, Hq, D)."""
    route = paged_attn_route()
    if route == "pallas":
        return _pa.paged_attention_pallas(q, k_pool, v_pool, block_tables,
                                          pos, k_scale, v_scale)
    if route == "gather":
        return _pa.paged_attention_gather(q, k_pool, v_pool, block_tables,
                                          pos, k_scale, v_scale)
    if route == "interpret":
        return _pa.paged_attention_pallas(q, k_pool, v_pool, block_tables,
                                          pos, k_scale, v_scale,
                                          interpret=True)
    return _pa.paged_attention_ref(q, k_pool, v_pool, block_tables, pos,
                                   k_scale, v_scale)
