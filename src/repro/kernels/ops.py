"""Jit'd dispatch wrappers around the Pallas kernels.

On TPU the Pallas kernel runs natively; on CPU (this container) the pure-jnp
oracle executes instead — identical semantics (tests assert allclose between
the interpret-mode kernel and the oracle).  Set ``REPRO_FORCE_INTERPRET=1`` to
route through ``pallas_call(interpret=True)`` on CPU (used by kernel tests).
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels import psi_matmul as _pk
from repro.kernels import ref as _ref


def _use_pallas() -> bool:
    return jax.default_backend() == "tpu"


def _force_interpret() -> bool:
    return os.environ.get("REPRO_FORCE_INTERPRET", "0") == "1"


def psi_matmul_2d(x2d: jnp.ndarray, wleaf: dict) -> jnp.ndarray:
    """(M, K) x serving-format weight dict -> (M, N)."""
    scale = wleaf["scale"].reshape(-1)
    if "planes" in wleaf:
        if _use_pallas():
            return _pk.psi_matmul_int5(x2d, wleaf["planes"], scale)
        if _force_interpret():
            return _pk.psi_matmul_int5(x2d, wleaf["planes"], scale, interpret=True)
        return _ref.psi_matmul_int5_ref(x2d, wleaf["planes"], scale)
    if _use_pallas():
        return _pk.psi_matmul_int8(x2d, wleaf["codes"], scale)
    if _force_interpret():
        return _pk.psi_matmul_int8(x2d, wleaf["codes"], scale, interpret=True)
    return _ref.psi_matmul_int8_ref(x2d, wleaf["codes"], scale)


def psi_matmul(x: jnp.ndarray, wleaf: dict) -> jnp.ndarray:
    """(..., K) x serving-format weight -> (..., N); flattens leading dims."""
    lead = x.shape[:-1]
    K = x.shape[-1]
    y = psi_matmul_2d(x.reshape(-1, K), wleaf)
    return y.reshape(*lead, y.shape[-1])
