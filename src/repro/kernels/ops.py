"""Jit'd dispatch wrappers around the Pallas kernels.

The serving weight arrives as a :class:`~repro.core.psi.QuantizedTensor`;
dispatch is typed — storage layout (``qt.packed``) picks the kernel body and
``qt.fmt.bits`` parameterizes it — with explicit backend routing (no silent
fall-through):

  * ``tpu``          -> the Pallas kernel (compressed weights in HBM,
                        VMEM dequantization);
  * ``gpu`` / ``cuda`` / ``rocm``
                     -> the dequantize-then-einsum fast path in
                        ``repro.kernels.ref`` (tensor-core-eligible dense
                        dot; the bit-plane loop has no Mosaic pipeline to
                        win on a GPU);
  * anything else (``cpu``) -> the pure-jnp oracle — identical semantics
                        (tests assert allclose between the interpret-mode
                        kernel and the oracle).

Set ``REPRO_FORCE_INTERPRET=1`` to route through
``pallas_call(interpret=True)`` on CPU (used by kernel tests).

Decode-shaped dispatch (DESIGN.md §2): the M-tile follows the actual row
count (``psi_matmul.pick_bm``), so a decode step over <=16 slots stops
padding M up to the 128-row MXU tile (8-16x fewer padded MACs per GEMV;
tracked by ``benchmarks/kernel_bench.py``).
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.core import psi
from repro.kernels import psi_matmul as _pk
from repro.kernels import ref as _ref

_GPU_BACKENDS = ("gpu", "cuda", "rocm")


def _backend() -> str:
    return jax.default_backend()


def _use_pallas() -> bool:
    return _backend() == "tpu"


def _use_gpu_fast_path() -> bool:
    return _backend() in _GPU_BACKENDS


def _force_interpret() -> bool:
    return os.environ.get("REPRO_FORCE_INTERPRET", "0") == "1"


def psi_matmul_2d(x2d: jnp.ndarray, qt: psi.QuantizedTensor) -> jnp.ndarray:
    """(M, K) x QuantizedTensor weight -> (M, N)."""
    scale = qt.scale.reshape(-1)
    bm = _pk.pick_bm(x2d.shape[0], x2d.dtype)
    if qt.packed:
        bits = qt.fmt.bits
        if _use_pallas():
            return _pk.psi_matmul_packed(x2d, qt.data, scale, bits=bits,
                                         bm=bm)
        if _use_gpu_fast_path():
            return _ref.psi_matmul_packed_dequant(x2d, qt.data, scale, bits)
        if _force_interpret():
            return _pk.psi_matmul_packed(x2d, qt.data, scale, bits=bits,
                                         bm=bm, interpret=True)
        return _ref.psi_matmul_packed_ref(x2d, qt.data, scale, bits)
    if _use_pallas():
        return _pk.psi_matmul_codes(x2d, qt.data, scale, bm=bm)
    if _use_gpu_fast_path():
        return _ref.psi_matmul_codes_dequant(x2d, qt.data, scale)
    if _force_interpret():
        return _pk.psi_matmul_codes(x2d, qt.data, scale, bm=bm,
                                    interpret=True)
    return _ref.psi_matmul_codes_ref(x2d, qt.data, scale)


def psi_matmul(x: jnp.ndarray, qt: psi.QuantizedTensor) -> jnp.ndarray:
    """(..., K) x QuantizedTensor weight -> (..., N); flattens leading dims."""
    lead = x.shape[:-1]
    K = x.shape[-1]
    y = psi_matmul_2d(x.reshape(-1, K), qt)
    return y.reshape(*lead, y.shape[-1])
