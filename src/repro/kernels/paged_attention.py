"""Pallas TPU kernel: fused paged-decode attention over the block-pool KV.

The serving decode step used to *gather* every slot's pool blocks into a
dense ``(B, n_bt*bs, Hkv, hd)`` temporary (dequantizing int8 pools into a
second temporary first) and only then attend — exactly the HBM round-trip
the TMA thesis says to eliminate (DESIGN.md §2: useful work per byte
moved).  This kernel walks each slot's block table directly instead:

  * Grid ``(B, n_bt)`` with the block-table entry as the *scalar-prefetched*
    HBM index — ``PrefetchScalarGridSpec`` lets the BlockSpec index_map pick
    pool block ``max(table[b, j], 0)`` so each referenced block is streamed
    through VMEM exactly once, straight out of the pool.  No gathered
    temporary ever exists.
  * Online softmax (flash-decode): per-slot running max ``m``, sum ``l`` and
    output accumulator ``acc`` live in VMEM scratch across the ``j`` walk.
  * Key positions are synthesized from the walk itself (logical block j,
    offset o -> ``j*bs + o``; entry −1 -> invalid), so stale pool contents
    past ``pos`` stay causally masked without a stored k_pos — the same
    contract the gather path implemented (DESIGN.md §3).
  * int8 pools (``kv_quant="int8"``) dequantize per-entry inside the same
    VMEM pass: codes * ``k_scale``/``v_scale`` right before the dot, so the
    low-bit representation stays live all the way into the compute unit
    (no dequantized HBM copy).

Rows whose table is entirely −1 (inactive slots) have no valid key and
return exactly zero — the serving engine discards those outputs host-side
(masked-decode contract).  The gather/oracle paths return the unmasked
softmax average there instead; tests only compare rows with >= 1 visible
key.

Routing lives in :mod:`repro.kernels.ops` (tpu -> this kernel, gpu -> the
dense-gather fast path below, cpu -> :func:`paged_attention_ref`, the
bit-level token-identity oracle).  Validated on CPU with ``interpret=True``
against the oracle by ``tests/test_paged_attention.py``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.psi_matmul import _CompilerParams

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Shared helpers (gather layout + synthesized positions).
# ---------------------------------------------------------------------------
def _gather(pool, block_tables):
    """pool (N, bs, ...) indexed by (B, n_bt) tables -> (B, n_bt*bs, ...).

    −1 entries clamp to block 0; callers mask them via the synthesized
    positions.  This *is* the dense temporary the Pallas kernel removes —
    kept here as the oracle/fast-path building block.
    """
    B, n_bt = block_tables.shape
    g = pool[jnp.maximum(block_tables, 0)]          # (B, n_bt, bs, ...)
    return g.reshape(B, n_bt * pool.shape[1], *pool.shape[2:])


def synth_positions(block_tables, block_size):
    """(B, n_bt) tables -> (B, n_bt*bs) absolute key positions; −1 entries
    (and everything in them) are invalid (−1)."""
    B, n_bt = block_tables.shape
    base = (jnp.arange(n_bt, dtype=jnp.int32)[None, :, None] * block_size
            + jnp.arange(block_size, dtype=jnp.int32)[None, None, :])
    return jnp.where(block_tables[:, :, None] >= 0, base,
                     -1).reshape(B, n_bt * block_size)


def _out_dtype(q, v_pool, v_scale):
    # quantized pools dequantize into the activation dtype; float pools keep
    # their own dtype (both match the pre-kernel gather path bit-for-bit).
    return q.dtype if v_scale is not None else v_pool.dtype


# ---------------------------------------------------------------------------
# CPU oracle: the token-identity reference.
# ---------------------------------------------------------------------------
@jax.jit
def paged_attention_ref(q, k_pool, v_pool, block_tables, pos,
                        k_scale=None, v_scale=None):
    """Pure-XLA oracle — the exact math of the pre-kernel gather read path.

    q (B, Hq, D); pools (N, bs, Hkv, D); block_tables (B, n_bt) int32
    (−1 = unallocated); pos (B,) absolute query positions; optional
    per-entry scales (N, bs, Hkv, 1) f32 for int8 pools.  Returns
    (B, Hq, D).  This is the token-identity reference: same einsum
    contractions, masking and dtype casts as ``attention.sdpa`` at Sq=1,
    so routing the decode step through it changes no serving token.
    """
    B, Hq, D = q.shape
    bs, Hkv = k_pool.shape[1], k_pool.shape[2]
    G = Hq // Hkv
    k = _gather(k_pool, block_tables)
    v = _gather(v_pool, block_tables)
    if k_scale is not None:
        k = (k.astype(jnp.float32)
             * _gather(k_scale, block_tables)).astype(q.dtype)
        v = (v.astype(jnp.float32)
             * _gather(v_scale, block_tables)).astype(q.dtype)
    k_pos = synth_positions(block_tables, bs)                   # (B, S)
    S = k_pos.shape[1]

    qg = q.reshape(B, 1, Hkv, G, D)                             # Sq = 1
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k,
                   preferred_element_type=jnp.float32)
    s = s.reshape(B, Hq, 1, S) * (D ** -0.5)
    m = (k_pos[:, None, :] >= 0) & (k_pos[:, None, :] <= pos[:, None, None])
    s = jnp.where(m[:, None], s, NEG_INF)                       # (B,Hq,1,S)
    p = jax.nn.softmax(s, axis=-1)
    pg = p.reshape(B, Hkv, G, 1, S)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", pg.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, 1, Hq, D).astype(v.dtype)[:, 0]


# ---------------------------------------------------------------------------
# GPU fast path: dense gather + one-shot softmax in the activation dtype.
# ---------------------------------------------------------------------------
@jax.jit
def paged_attention_gather(q, k_pool, v_pool, block_tables, pos,
                           k_scale=None, v_scale=None):
    """Dense-gather fast path for non-TPU accelerators: materialize the
    gathered (and dequantized) KV once in the activation dtype and run a
    single tensor-core-eligible masked attention.  Same masking semantics
    as the oracle; accumulation order (one dense softmax vs the oracle's
    f32 upcast chain) may differ in the last ulp."""
    B, Hq, D = q.shape
    bs, Hkv = k_pool.shape[1], k_pool.shape[2]
    G = Hq // Hkv
    act = _out_dtype(q, v_pool, v_scale)
    k = _gather(k_pool, block_tables)
    v = _gather(v_pool, block_tables)
    if k_scale is not None:
        k = (k.astype(jnp.float32)
             * _gather(k_scale, block_tables)).astype(act)
        v = (v.astype(jnp.float32)
             * _gather(v_scale, block_tables)).astype(act)
    k_pos = synth_positions(block_tables, bs)
    S = k_pos.shape[1]
    s = jnp.einsum("bhgd,bkhd->bhgk", q.reshape(B, Hkv, G, D), k,
                   preferred_element_type=jnp.float32) * (D ** -0.5)
    m = (k_pos >= 0) & (k_pos <= pos[:, None])                  # (B, S)
    s = jnp.where(m[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)                              # (B,Hkv,G,S)
    o = jnp.einsum("bhgk,bkhd->bhgd", p.astype(act), v,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, Hq, D).astype(act)


# ---------------------------------------------------------------------------
# The Pallas kernel.
# ---------------------------------------------------------------------------
def _paged_kernel_body(bt_ref, pos_ref, q_ref, k_ref, v_ref, o_ref,
                       m_ref, l_ref, acc_ref, *, bs, n_bt, n_kv, group,
                       quantized, ks_ref=None, vs_ref=None):
    """One (slot b, table entry j) grid step of the VMEM streaming walk."""
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    entry = bt_ref[b, j]                                 # scalar-prefetched
    q = q_ref[0].astype(jnp.float32)                     # (Hq, D)
    kb = k_ref[0]                                        # (bs, Hkv, D)
    vb = v_ref[0]
    if quantized:
        # fused dequant: codes * per-entry scale, inside VMEM, no HBM copy
        kb = kb.astype(jnp.float32) * ks_ref[0]
        vb = vb.astype(jnp.float32) * vs_ref[0]
    else:
        kb = kb.astype(jnp.float32)
        vb = vb.astype(jnp.float32)
    D = q.shape[-1]

    # grouped scores (Hq, bs): static loop over KV heads keeps every dot a
    # plain (G, D) x (D, bs) MXU contraction (no batched dot_general).
    s = jnp.concatenate(
        [jax.lax.dot_general(q[h * group:(h + 1) * group], kb[:, h, :],
                             (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
         for h in range(n_kv)], axis=0) * (D ** -0.5)

    # synthesized key positions: entry −1 -> whole block invalid; offsets
    # past the query position -> causally masked (covers stale pool rows).
    k_pos = j * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)
    ok = (entry >= 0) & (k_pos <= pos_ref[b])            # (1, bs)
    s = jnp.where(ok, s, NEG_INF)

    # online-softmax update.  p is re-masked (not just exp'd) so an
    # all-invalid prefix (m still == NEG_INF) contributes exactly zero.
    m_prev = m_ref[...]                                  # (Hq, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.where(ok, jnp.exp(s - m_new), 0.0)           # (Hq, bs)
    l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=-1, keepdims=True)
    pv = jnp.concatenate(
        [jax.lax.dot_general(p[h * group:(h + 1) * group], vb[:, h, :],
                             (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
         for h in range(n_kv)], axis=0)                  # (Hq, D)
    acc_ref[...] = alpha * acc_ref[...] + pv
    m_ref[...] = m_new

    @pl.when(j == n_bt - 1)
    def _epilogue():
        l = l_ref[...]
        # no visible key at all (inactive slot): exact zero output
        o_ref[0] = (acc_ref[...] / jnp.where(l == 0.0, 1.0, l)
                    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention_pallas(q, k_pool, v_pool, block_tables, pos,
                           k_scale=None, v_scale=None, *, interpret=False):
    """Flash-decode paged attention: stream pool blocks through VMEM one
    block-table entry at a time.  Same signature/semantics as
    :func:`paged_attention_ref` (to fp32 accumulation-order tolerance;
    exactly for the masking pattern)."""
    B, Hq, D = q.shape
    N, bs, Hkv, _ = k_pool.shape
    n_bt = block_tables.shape[1]
    group = Hq // Hkv
    quantized = k_scale is not None
    block_tables = block_tables.astype(jnp.int32)
    pos = pos.astype(jnp.int32)

    def _pool_idx(b, j, bt_ref, pos_ref):
        # −1 (unallocated) clamps to block 0; its contributions are masked
        # in-kernel, so the load is a harmless (already-resident) prefetch.
        return (jnp.maximum(bt_ref[b, j], 0), 0, 0, 0)

    in_specs = [
        pl.BlockSpec((1, Hq, D), lambda b, j, bt, pp: (b, 0, 0)),
        pl.BlockSpec((1, bs, Hkv, D), _pool_idx),
        pl.BlockSpec((1, bs, Hkv, D), _pool_idx),
    ]
    operands = [q, k_pool, v_pool]
    if quantized:
        in_specs += [pl.BlockSpec((1, bs, Hkv, 1), _pool_idx),
                     pl.BlockSpec((1, bs, Hkv, 1), _pool_idx)]
        operands += [k_scale, v_scale]

    body = functools.partial(
        _paged_kernel_body, bs=bs, n_bt=n_bt, n_kv=Hkv, group=group,
        quantized=quantized)
    if quantized:
        # scale refs ride after v_ref in the positional operand order
        def kernel(bt, pp, qr, kr, vr, ksr, vsr, orf, mr, lr, ar):
            body(bt, pp, qr, kr, vr, orf, mr, lr, ar, ks_ref=ksr, vs_ref=vsr)
    else:
        kernel = body

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, n_bt),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, Hq, D), lambda b, j, bt, pp: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((Hq, 1), jnp.float32),            # running max m
            pltpu.VMEM((Hq, 1), jnp.float32),            # running sum l
            pltpu.VMEM((Hq, D), jnp.float32),            # output accumulator
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(
            (B, Hq, D), _out_dtype(q, v_pool, v_scale)),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(block_tables, pos, *operands)
    return out


# ---------------------------------------------------------------------------
# Traffic model (benchmarks/kernel_bench.py + CI assert on BENCH_kernel.json).
# ---------------------------------------------------------------------------
def gathered_bytes(B, n_bt, bs, n_kv, head_dim, *, quantized,
                   act_bytes=2):
    """Bytes of dense temporaries the *gather* read path materializes per
    decode step per layer — the quantity the Pallas kernel eliminates.

    K and V each gather (B, n_bt*bs, Hkv, hd) in the pool dtype; int8 pools
    additionally gather the per-entry scales and materialize a second,
    dequantized activation-dtype copy of both tensors."""
    entries = B * n_bt * bs * n_kv
    pool_bytes = 1 if quantized else act_bytes
    total = 2 * entries * head_dim * pool_bytes          # gathered K + V
    if quantized:
        total += 2 * entries * 4                         # gathered scales
        total += 2 * entries * head_dim * act_bytes      # dequantized copies
    return total


def streamed_bytes(n_valid_entries, bs, n_kv, head_dim, *, quantized,
                   act_bytes=2):
    """Pool bytes the kernel actually streams through VMEM: each *valid*
    block-table entry's K and V block (plus scales when quantized), read
    once, never re-materialized."""
    per_entry = bs * n_kv * head_dim * (1 if quantized else act_bytes)
    total = 2 * n_valid_entries * per_entry
    if quantized:
        total += 2 * n_valid_entries * bs * n_kv * 4
    return total
