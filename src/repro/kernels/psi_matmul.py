"""Pallas TPU kernel: matmul with PSI-compressed weights, dequantized in VMEM.

TPU-native adaptation of the paper's multiplier-less SAM array (DESIGN.md §2):
the ASIC removes multiplier *gates*; on TPU the scarce resource in the
memory-bound serving regime is HBM bandwidth, so the PSI code (5 or 8 bits per
weight instead of 16) is kept compressed in HBM and expanded to bf16 *inside
VMEM*, right before the MXU.  Weight HBM traffic drops 2x (INT8) / 3.2x (INT5
bit-planes) versus bf16 weights.

Layout / tiling:
  * Grid (M/bm, N/bn, K/bk); K is the innermost ("arbitrary") dimension and
    accumulates into a VMEM f32 scratch; the per-output-channel scale is
    applied once in the epilogue (k == K/bk - 1).
  * int8 codes: tile (bk, bn) int8 -> bf16 convert -> MXU dot (any registered
    width's codes — the storage is one byte regardless of PsiFormat.bits).
  * packed sub-byte: bit-plane tile (bits, bk//8, bn) uint8; the kernel
    rebuilds the offset-binary value with ``bits`` shift-adds (the SAM
    barrel-shifter mirror), subtracts 2^(bits-1), converts, dots.  One kernel
    body serves every sub-byte width in the PsiFormat registry — ``bits`` is
    a static argument baked per format at trace time.
  * bm/bn/bk default 128/128/128 — MXU-aligned (multiples of 128 on the
    matmul dims), VMEM footprint per step ~ bm*bk*2 + bk*bn + bm*bn*4
    ≈ 128 KiB, far under the ~16 MiB/core budget, leaving room for
    double-buffered pipelining by the Mosaic compiler.

Validated on CPU with ``interpret=True`` against ``repro.kernels.ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


DEFAULT_BM, DEFAULT_BN, DEFAULT_BK = 128, 128, 128

# Minimum legal sublane (second-to-minor) tile per dtype — the Mosaic
# register-tiling floor.  The lane (minor) dim stays at 128 always.
_MIN_SUBLANE = {jnp.dtype(jnp.bfloat16): 16, jnp.dtype(jnp.float32): 8}


def pick_bm(M: int, dtype=jnp.float32) -> int:
    """Decode-shaped M-tile dispatch (DESIGN.md §2).

    The serving decode step calls the kernel at M = active slots (1-16);
    padding M up to the square 128-row tile makes the MXU grind 8-16x
    zero rows per (n, k) grid step.  Pick the smallest legal sublane
    multiple covering M instead (f32: 8, bf16: 16) — same kernel body,
    same numerics (the block-shape-sweep tests assert invariance), just a
    shorter M tile.  Large M keeps the square MXU-aligned default.
    """
    if M >= DEFAULT_BM:
        return DEFAULT_BM
    lo = _MIN_SUBLANE.get(jnp.dtype(dtype), 8)
    return max(lo, -(-M // lo) * lo)


def padded_macs(M: int, K: int, N: int, *, bm: int = DEFAULT_BM,
                bn: int = DEFAULT_BN, bk: int = DEFAULT_BK) -> int:
    """MACs the tiled kernel actually issues once every dim is padded up to
    its tile multiple — the quantity the decode-shaped dispatch cuts and
    ``benchmarks/kernel_bench.py`` tracks."""
    mp = -(-M // bm) * bm
    kp = -(-K // bk) * bk
    np_ = -(-N // bn) * bn
    return mp * kp * np_

# jax 0.5 renamed pltpu.TPUCompilerParams -> CompilerParams; accept both so
# the kernels (and their interpret-mode tests) run across the 0.4/0.5 pin.
# A future rename fails loudly here at import, not inside pallas_call.
_CompilerParams = (getattr(pltpu, "CompilerParams", None)
                   or pltpu.TPUCompilerParams)


def _int8_kernel(x_ref, codes_ref, scale_ref, o_ref, acc_ref, *, k_steps):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]                                  # (bm, bk) bf16/f32
    w = codes_ref[...].astype(x.dtype)              # (bk, bn) int8 -> act dtype
    acc_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k == k_steps - 1)
    def _done():
        o_ref[...] = (acc_ref[...] * scale_ref[...]).astype(o_ref.dtype)


def _packed_kernel(x_ref, planes_ref, scale_ref, o_ref, acc_ref, *, k_steps,
                   bits):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]                                   # (bm, bk)
    planes = planes_ref[...]                         # (bits, bk//8, bn) uint8
    _, kb, bn = planes.shape
    # SAM-mirror reconstruction: ``bits`` shift-adds rebuild the offset-binary
    # weight; lane index selects the bit within each packed byte.
    lane = jax.lax.broadcasted_iota(jnp.int32, (kb, 8, bn), 1)
    val = jnp.zeros((kb, 8, bn), jnp.int32)
    for b in range(bits):
        plane = planes[b].astype(jnp.int32)[:, None, :]   # (kb, 1, bn)
        bit = (plane >> lane) & 1
        val = val + (bit << b)
    offset = 1 << (bits - 1)
    w = (val.reshape(kb * 8, bn) - offset).astype(x.dtype)  # (bk, bn)
    acc_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k == k_steps - 1)
    def _done():
        o_ref[...] = (acc_ref[...] * scale_ref[...]).astype(o_ref.dtype)


def _pad_to(a, mult, axis):
    pad = (-a.shape[axis]) % mult
    if pad == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def psi_matmul_int8(x, codes, scale, *, bm=DEFAULT_BM, bn=DEFAULT_BN,
                    bk=DEFAULT_BK, interpret=False):
    """x (M, K) @ dequant(codes (K, N) int8, scale (N,)) -> (M, N).

    Serves every *unpacked* PsiFormat — sub-byte codes are stored int8, so
    the kernel body is width-independent (``psi_matmul_codes`` is the
    format-neutral alias ``repro.kernels.ops`` dispatches through)."""
    M, K = x.shape
    Kc, N = codes.shape
    assert K == Kc
    xp = _pad_to(_pad_to(x, bm, 0), bk, 1)
    cp = _pad_to(_pad_to(codes, bk, 0), bn, 1)
    sp = _pad_to(scale.reshape(1, -1), bn, 1)
    Mp, Kp = xp.shape
    _, Np = cp.shape
    k_steps = Kp // bk
    grid = (Mp // bm, Np // bn, k_steps)
    out = pl.pallas_call(
        functools.partial(_int8_kernel, k_steps=k_steps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda m, n, k: (m, k)),
            pl.BlockSpec((bk, bn), lambda m, n, k: (k, n)),
            pl.BlockSpec((1, bn), lambda m, n, k: (0, n)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda m, n, k: (m, n)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(xp, cp, sp)
    return out[:M, :N]


# Format-neutral alias: any registered width's unpacked codes are int8.
psi_matmul_codes = psi_matmul_int8


@functools.partial(jax.jit,
                   static_argnames=("bits", "bm", "bn", "bk", "interpret"))
def psi_matmul_packed(x, planes, scale, *, bits, bm=DEFAULT_BM,
                      bn=DEFAULT_BN, bk=DEFAULT_BK, interpret=False):
    """x (M, K) @ dequant(planes (bits, K//8, N) uint8, scale (N,)) -> (M, N).

    ``bits`` is the PsiFormat width (static) — the same kernel body serves
    every registered sub-byte format.
    """
    assert bk % 8 == 0
    M, K = x.shape
    nb, Kb, N = planes.shape
    assert nb == bits and Kb * 8 == K, (planes.shape, x.shape, bits)
    xp = _pad_to(_pad_to(x, bm, 0), bk, 1)
    pp = _pad_to(_pad_to(planes, bk // 8, 1), bn, 2)
    # padded plane bytes are 0 -> unpack to -2^(bits-1); cancelled because x
    # is zero-padded on K, so the extra columns multiply zeros.  Pad x K first.
    sp = _pad_to(scale.reshape(1, -1), bn, 1)
    Mp, Kp = xp.shape
    Np = pp.shape[2]
    k_steps = Kp // bk
    grid = (Mp // bm, Np // bn, k_steps)
    out = pl.pallas_call(
        functools.partial(_packed_kernel, k_steps=k_steps, bits=bits),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda m, n, k: (m, k)),
            pl.BlockSpec((bits, bk // 8, bn), lambda m, n, k: (0, k, n)),
            pl.BlockSpec((1, bn), lambda m, n, k: (0, n)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda m, n, k: (m, n)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(xp, pp, sp)
    return out[:M, :N]


def psi_matmul_int5(x, planes, scale, **kw):
    """INT5 instance of :func:`psi_matmul_packed` (kept as the named entry
    point for the paper's Table-I width)."""
    return psi_matmul_packed(x, planes, scale, bits=5, **kw)
