"""Pure-jnp oracles for the Pallas kernels.

These define the exact semantics the kernels must match (asserted in
tests/test_kernels.py across shape/dtype sweeps) and serve as the CPU
execution path of ``repro.kernels.ops``.  Every oracle is parameterized by
the weight's :class:`~repro.core.psi.PsiFormat` width — one code path per
storage layout (int8 codes vs bit-planes), not per format.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import psi


def psi_matmul_codes_ref(x: jnp.ndarray, codes: jnp.ndarray,
                         scale: jnp.ndarray) -> jnp.ndarray:
    """x (..., K) @ dequant(codes (K, N), scale (1, N) or (N,)) -> (..., N).

    Accumulates in f32 (MXU-accurate), applies the per-output-channel scale
    after the reduction — bit-matching the kernel's epilogue.  Width-neutral:
    any registered format's unpacked codes are int8.
    """
    acc = jnp.einsum("...k,kn->...n", x.astype(jnp.float32),
                     codes.astype(jnp.float32),
                     preferred_element_type=jnp.float32)
    return (acc * scale.reshape(1, -1)).astype(x.dtype)


def psi_matmul_packed_ref(x: jnp.ndarray, planes: jnp.ndarray,
                          scale: jnp.ndarray, bits: int) -> jnp.ndarray:
    """x (..., K) @ dequant(planes (bits, K//8, N), scale) -> (..., N).

    The bit-plane unpack (sum of shifted bits − 2^(bits-1)) is the software
    mirror of the SAM barrel-shift reconstruction (paper Fig. 2 /
    DESIGN.md §2).
    """
    codes = psi.unpack_codes(planes, bits)
    return psi_matmul_codes_ref(x, codes, scale)


# Named instances of the paper's Table-I widths (kept as the test-facing
# entry points).
psi_matmul_int8_ref = psi_matmul_codes_ref


def psi_matmul_int5_ref(x: jnp.ndarray, planes: jnp.ndarray,
                        scale: jnp.ndarray) -> jnp.ndarray:
    return psi_matmul_packed_ref(x, planes, scale, 5)


# ---------------------------------------------------------------------------
# Non-TPU accelerator fast path: dequantize once, single dense dot.
#
# GPUs have no Mosaic/VMEM pipeline, so the bit-plane loop and the f32
# oracle einsum both miss the tensor cores.  Folding the per-output-channel
# scale into the weight and casting to the activation dtype BEFORE the dot
# keeps the matmul a plain x.dtype @ x.dtype contraction (tensor-core
# eligible, f32 accumulation) — mathematically identical to the oracle's
# scale-in-the-epilogue because the scale only varies along the output dim.
# ---------------------------------------------------------------------------
def psi_matmul_codes_dequant(x: jnp.ndarray, codes: jnp.ndarray,
                             scale: jnp.ndarray) -> jnp.ndarray:
    w = (codes.astype(jnp.float32) * scale.reshape(1, -1)).astype(x.dtype)
    y = jnp.einsum("...k,kn->...n", x, w,
                   preferred_element_type=jnp.float32)
    return y.astype(x.dtype)


def psi_matmul_packed_dequant(x: jnp.ndarray, planes: jnp.ndarray,
                              scale: jnp.ndarray, bits: int) -> jnp.ndarray:
    return psi_matmul_codes_dequant(x, psi.unpack_codes(planes, bits), scale)


psi_matmul_int8_dequant = psi_matmul_codes_dequant


def psi_matmul_int5_dequant(x: jnp.ndarray, planes: jnp.ndarray,
                            scale: jnp.ndarray) -> jnp.ndarray:
    return psi_matmul_packed_dequant(x, planes, scale, 5)
