"""Pure-jnp oracles for the Pallas kernels.

These define the exact semantics the kernels must match (asserted in
tests/test_kernels.py across shape/dtype sweeps) and serve as the CPU
execution path of ``repro.kernels.ops``.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import psi


def psi_matmul_int8_ref(x: jnp.ndarray, codes: jnp.ndarray,
                        scale: jnp.ndarray) -> jnp.ndarray:
    """x (..., K) @ dequant(codes (K, N), scale (1, N) or (N,)) -> (..., N).

    Accumulates in f32 (MXU-accurate), applies the per-output-channel scale
    after the reduction — bit-matching the kernel's epilogue.
    """
    acc = jnp.einsum("...k,kn->...n", x.astype(jnp.float32),
                     codes.astype(jnp.float32),
                     preferred_element_type=jnp.float32)
    return (acc * scale.reshape(1, -1)).astype(x.dtype)


def psi_matmul_int5_ref(x: jnp.ndarray, planes: jnp.ndarray,
                        scale: jnp.ndarray) -> jnp.ndarray:
    """x (..., K) @ dequant(planes (5, K//8, N), scale) -> (..., N).

    The bit-plane unpack (sum of shifted bits − 16) is the software mirror of
    the SAM barrel-shift reconstruction (paper Fig. 2 / DESIGN.md §2).
    """
    codes = psi.unpack_int5(planes)
    return psi_matmul_int8_ref(x, codes, scale)
