"""Elastic scaling + failure handling.

At 1000+ nodes, hardware loss is routine.  The recovery contract here:

1. Every N steps the CheckpointManager persists (params, opt_state, data
   state) with *global* array layouts.
2. On failure, the coordinator restarts the job on the surviving slice;
   ``plan_remesh`` picks the largest valid mesh for the new device count.
3. ``CheckpointManager.restore(shardings=...)`` reshards every leaf onto the
   new mesh — no resharding tool step, it is the load path itself.
4. The data pipeline's state is one integer; after re-sharding hosts resume
   the exact global sample sequence (repro.data.pipeline).

``plan_remesh`` prefers shrinking the data axis first (keeps TP intact, so
per-device weight shards — and therefore compiled executables — are reusable
across restarts with the same model axis).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import numpy as np


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    shape: Tuple[int, ...]
    axis_names: Tuple[str, ...]
    dropped_devices: int

    @property
    def n_devices(self) -> int:
        return int(np.prod(self.shape))


def plan_remesh(n_available: int, model_parallel: int,
                pods: Optional[int] = None) -> MeshPlan:
    """Largest (data, model) mesh with the given TP degree that fits the
    surviving device count; excess devices become hot spares."""
    if n_available < model_parallel:
        raise ValueError(
            f"cannot keep TP={model_parallel} with {n_available} devices")
    data = n_available // model_parallel
    if pods and pods > 1 and data % pods == 0:
        shape = (pods, data // pods, model_parallel)
        names = ("pod", "data", "model")
    else:
        shape = (data, model_parallel)
        names = ("data", "model")
    used = int(np.prod(shape))
    return MeshPlan(shape, names, n_available - used)


def make_mesh_from_plan(plan: MeshPlan, devices=None):
    devices = devices if devices is not None else jax.devices()
    usable = np.asarray(devices[:plan.n_devices]).reshape(plan.shape)
    return jax.sharding.Mesh(usable, plan.axis_names)


def survivors_after_failure(devices, failed_ids) -> list:
    failed = set(failed_ids)
    return [d for d in devices if d.id not in failed]
