"""Straggler detection: per-host step-time heartbeats with robust z-scores.

In a synchronous data-parallel step the slowest host sets the pace; at pod
scale a single degraded host (thermal throttle, flaky HBM, loud neighbor on
the ICI) silently taxes every step.  The monitor keeps an EWMA of each
host's step time, flags hosts slower than ``threshold`` x the fleet median
for ``patience`` consecutive windows, and recommends eviction (which feeds
repro.runtime.elastic.plan_remesh).

The mitigation ladder (documented for the launcher):
  1. flag + log (this module),
  2. re-balance input shards away from the slow host (data pipeline takes
     host weights),
  3. evict + re-mesh from checkpoint (elastic.py).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

import numpy as np


@dataclasses.dataclass
class StragglerMonitor:
    n_hosts: int
    alpha: float = 0.2            # EWMA smoothing
    threshold: float = 1.25       # x median
    patience: int = 3

    def __post_init__(self):
        self.ewma = np.zeros(self.n_hosts)
        self.strikes = np.zeros(self.n_hosts, np.int32)
        self.initialized = False

    def observe(self, step_times: List[float]) -> Dict:
        t = np.asarray(step_times, np.float64)
        if not self.initialized:
            self.ewma[:] = t
            self.initialized = True
        else:
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * t
        med = np.median(self.ewma)
        slow = self.ewma > self.threshold * med
        self.strikes = np.where(slow, self.strikes + 1, 0)
        flagged = np.nonzero(self.strikes >= self.patience)[0].tolist()
        return {
            "median_s": float(med),
            "slowest_host": int(np.argmax(self.ewma)),
            "slowdown": float(self.ewma.max() / max(med, 1e-12)),
            "flagged_hosts": flagged,
            "evict_recommended": bool(flagged),
        }

    def input_weights(self) -> np.ndarray:
        """Relative data-shard weights for soft rebalancing (step 2 of the
        ladder): inverse of smoothed step time, normalized."""
        if not self.initialized:
            return np.ones(self.n_hosts) / self.n_hosts
        w = 1.0 / np.maximum(self.ewma, 1e-9)
        return w / w.sum()
