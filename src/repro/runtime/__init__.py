from repro.runtime.sharding import (  # noqa: F401
    param_specs, batch_specs, cache_specs, block_cache_specs,
    serve_batch_specs, batch_shard_count, slot_shard_map, block_shard_map,
    FSDP_AXIS, DP_AXES,
)
from repro.runtime.executor import Executor, single_device_mesh  # noqa: F401
