from repro.runtime.sharding import (  # noqa: F401
    param_specs, batch_specs, cache_specs, FSDP_AXIS, DP_AXES,
)
