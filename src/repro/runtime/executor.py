"""Mesh-native execution substrate for the serving engine (DESIGN.md §5).

The Executor is the device half of the serving stack: it owns the mesh
lifecycle, `NamedSharding` placement for every leaf (PSI-quantized params,
the slot-based decode cache, decode-step inputs), and the jit compilation +
donation contract for the serving entry points — prefill, decode_step, and
cache insert/slice.  `repro.launch.serve.Server` is the host half (scheduler
loop, buckets, accounting) and routes ALL device work through one Executor,
so there is exactly one compilation path whether the mesh has 1 device or a
pod.

Placement contract (derived in ``repro.runtime.sharding``):
  * params: tensor-parallel over "model" (quantized codes/planes follow the
    logical weight rule; scale shards only its non-singleton dims);
  * decode cache + decode inputs: slot dim over the data axes — the
    scheduler partitions slots into per-shard pools via ``slot_shard_map``;
  * donation: the engine cache is donated at every entry point that
    consumes it (decode, fused prefill+insert, burst insert) — the caller
    rebinds the returned cache, and XLA aliases the update in place.

Elastic integration (single-device path is a no-op): ``from_devices`` sizes
the mesh with ``elastic.plan_remesh``; ``remesh`` rebuilds the Executor on a
surviving device count, resharding params by device_put (the load path
itself); a ``StragglerMonitor`` is attached only when more than one process
participates.
"""
from __future__ import annotations

import warnings

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import quantizer
from repro.core.psi import QuantizedTensor
from repro.kernels import ops
from repro.models import kvcache as kvc
from repro.runtime import sharding as shr
from repro.runtime.elastic import make_mesh_from_plan, plan_remesh
from repro.runtime.straggler import StragglerMonitor


def single_device_mesh():
    """The degenerate (1, 1) data x model mesh: every spec resolves to
    replicated-on-one-device, so the Executor's single-device behavior is
    bit-identical to unsharded jit."""
    dev = np.asarray(jax.devices()[:1]).reshape(1, 1)
    return jax.sharding.Mesh(dev, ("data", "model"))


class DeviceBlockTable:
    """Host-mirrored, device-resident block table (DESIGN.md §3 "Multi-step
    decode & host overlap").

    The host (max_batch, n_bt) int32 mirror stays authoritative — the
    scheduler reads and writes it exactly like the plain ndarray it
    replaces — but every ``__setitem__`` records which slot rows went dirty,
    and :meth:`device` refreshes the cached device copy INCREMENTALLY: an
    unchanged table returns the same committed device array (zero host->
    device transfer, regression-tested), a few dirty rows go up as one-row
    scatters through a single jitted ``at[slot].set(row)`` executable, and
    only a mostly-rewritten table falls back to a full upload.  ``stats``
    counts each path so the serve loop can report transfer behavior.
    """

    def __init__(self, executor: "Executor"):
        if not executor.paged:
            raise ValueError("DeviceBlockTable mirrors the paged layout's "
                             "block table; this executor is dense")
        self._ex = executor
        self.host = np.full((executor.max_batch, executor.n_bt), -1,
                            np.int32)
        self._device = None
        self._dirty = set()
        self.version = 0                       # host mutation counter
        self.stats = {"reuses": 0, "row_updates": 0, "full_uploads": 0}

    @property
    def shape(self):
        return self.host.shape

    def __getitem__(self, idx):
        return self.host[idx]

    def __setitem__(self, idx, val):
        self.host[idx] = val
        slot = idx[0] if isinstance(idx, tuple) else idx
        for s in np.atleast_1d(np.asarray(slot)).reshape(-1):
            self._dirty.add(int(s))
        self.version += 1

    def device(self):
        """The table as a committed device array in the decode-step input
        sharding, refreshed only where the host mirror changed since the
        last call."""
        sh = self._ex._step_shardings["block_table"]
        if self._device is None or 2 * len(self._dirty) >= self.host.shape[0]:
            self._device = jax.device_put(jnp.asarray(self.host), sh)
            self.stats["full_uploads"] += 1
        elif self._dirty:
            # one (n_bt,) row per dirty slot through the shared scatter
            # executable — NOT donated: an in-flight pipelined round may
            # still be reading the previous table version.
            for s in sorted(self._dirty):
                self._device = self._ex._bt_set_row(
                    self._device, jnp.int32(s), jnp.asarray(self.host[s]))
            self.stats["row_updates"] += len(self._dirty)
        else:
            self.stats["reuses"] += 1
        self._dirty.clear()
        return self._device


class Executor:
    """Owns mesh, placement, and the compiled serving entry points."""

    def __init__(self, cfg, params, *, max_batch: int, max_seq: int,
                 mesh=None, model=None, n_blocks: int = None,
                 speculative=None, decode_horizon: int = 1):
        if model is None:
            from repro.models import build_model   # lazy: models imports us
            model = build_model(cfg)
        self.cfg = cfg
        self.model = model
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.mesh = mesh if mesh is not None else single_device_mesh()
        self.dtype = jnp.dtype(cfg.dtype)

        # ---- cache layout (DESIGN.md §3): dense slot slabs or a paged
        # block pool driven by host-side block tables ----
        self.layout = cfg.resolved_cache_layout
        self.paged = self.layout == kvc.PAGED
        self.block_size = cfg.cache_block_size if self.paged else 0
        if self.paged:
            # logical blocks a slot can address; the pool adds max_batch
            # scratch blocks for masked/inactive writes
            self.n_bt = kvc.table_width(max_seq, self.block_size)
            self.n_blocks = (n_blocks if n_blocks is not None
                             else max_batch * self.n_bt)
            # resolved read-side route for the decode step (kernels.ops:
            # pallas / gather / ref / interpret).  Pinned at construction so
            # serve stats report the route the compiled executable actually
            # traced — the backend cannot change under a live Executor.
            self.paged_attn_route = ops.paged_attn_route()
        else:
            self.n_bt = 0
            self.n_blocks = 0
            self.paged_attn_route = None
            if n_blocks is not None:
                raise ValueError("n_blocks only applies to the paged cache "
                                 "layout (cfg.resolved_cache_layout)")

        # ---- self-speculative decoding (DESIGN.md §"Self-speculative
        # decoding"): the draft model is a narrower PSI view of the SAME
        # checkpoint, derived code-space from the serving leaves ----
        self.speculative = tuple(speculative) if speculative else None
        if self.speculative is not None:
            bits, k = self.speculative
            if not self.paged:
                raise ValueError("speculative decoding needs the paged "
                                 "cache layout (cfg.resolved_cache_layout)")
            if cfg.rope == "mrope":
                raise ValueError("speculative verify does not support "
                                 "mrope position encoding")
            if not 1 <= k <= self.block_size:
                raise ValueError(
                    f"speculative k={k} must be in [1, block_size="
                    f"{self.block_size}]: the k-token verify scatter needs "
                    f"distinct in-block offsets")
            if not any(isinstance(leaf, QuantizedTensor)
                       for leaf in jax.tree_util.tree_leaves(
                           params, is_leaf=lambda x: isinstance(
                               x, QuantizedTensor))):
                raise ValueError("speculative decoding derives its draft "
                                 "from PSI-quantized serving params; "
                                 "quantize first (--quant psiN)")
            self.spec_bits, self.spec_k = bits, k
        else:
            self.spec_bits = self.spec_k = 0

        # ---- multi-step decode (DESIGN.md §3 "Multi-step decode & host
        # overlap"): a horizon-M on-device token loop with in-kernel
        # retirement; M = 1 keeps the classic one-step path untraced ----
        self.decode_horizon = int(decode_horizon) if decode_horizon else 1
        if self.decode_horizon < 1:
            raise ValueError(
                f"decode_horizon={decode_horizon} must be >= 1")
        if self.decode_horizon > 1 and self.speculative is not None:
            raise ValueError(
                "--decode-horizon > 1 does not compose with --speculative: "
                "a speculative round is already a fused multi-token device "
                "unit with its own acceptance loop — pick ONE multi-token "
                "decode strategy (drop --speculative or set the horizon "
                "to 1)")

        # ---- placement: params now, cache/input shardings precomputed ----
        self.param_shardings = shr.to_shardings(
            shr.param_specs(params, cfg, self.mesh, mode="serve"), self.mesh)
        self.params = jax.device_put(params, self.param_shardings)
        if self.speculative is not None:
            draft = quantizer.draft_param_tree(params, self.spec_bits)
            self.draft_shardings = shr.to_shardings(
                shr.param_specs(draft, cfg, self.mesh, mode="serve"),
                self.mesh)
            self.draft_params = jax.device_put(draft, self.draft_shardings)
        else:
            self.draft_params = None

        cache_shape = jax.eval_shape(
            lambda: self._init_cache_fn())
        self.cache_shardings = shr.to_shardings(
            shr.cache_specs(cfg, self.mesh, cache_shape), self.mesh)

        step_inputs = {
            "token": jax.ShapeDtypeStruct((max_batch, 1), jnp.int32),
            "pos": jax.ShapeDtypeStruct((max_batch, 1), jnp.int32),
            "active": jax.ShapeDtypeStruct((max_batch,), jnp.bool_),
        }
        if self.paged:
            step_inputs["block_table"] = jax.ShapeDtypeStruct(
                (max_batch, self.n_bt), jnp.int32)
        if self.speculative is not None:
            # the verify pass feeds k tokens per slot; same slot-over-data
            # rule as every other step input (dim 0 is the slot dim)
            step_inputs["spec_tokens"] = jax.ShapeDtypeStruct(
                (max_batch, self.spec_k), jnp.int32)
        if self.decode_horizon > 1:
            # per-slot emission budget for the in-kernel retirement mask
            step_inputs["remaining"] = jax.ShapeDtypeStruct(
                (max_batch,), jnp.int32)
        self._step_shardings = shr.to_shardings(
            shr.serve_batch_specs(cfg, self.mesh, step_inputs), self.mesh)

        # ---- slot/block partitioning for the mesh-aware scheduler ----
        self.n_slot_shards = shr.batch_shard_count(cfg, self.mesh, max_batch)
        self.slot_shards = shr.slot_shard_map(cfg, self.mesh, max_batch)
        if self.paged:
            n_total = self.n_blocks + max_batch
            self.n_block_shards = shr.batch_shard_count(cfg, self.mesh,
                                                        n_total)
            self.block_shards = shr.block_shard_map(cfg, self.mesh, n_total,
                                                    self.n_blocks)
        else:
            self.n_block_shards = 1
            self.block_shards = None
        dp_extent = int(np.prod([self.mesh.shape[a] for a in shr.DP_AXES
                                 if a in self.mesh.axis_names] or [1]))
        if self.n_slot_shards < dp_extent:
            # an explicitly requested data axis the slots cannot use should
            # be loud, not a silently replicated cache + dead parallelism
            warnings.warn(
                f"max_batch={max_batch} does not divide the mesh's "
                f"{dp_extent}-way data parallelism; decode slots shard only "
                f"{self.n_slot_shards}-way (rest of the data axis idles and "
                f"the cache replicates across it).  Pick max_batch a "
                f"multiple of the data-axis extent.", stacklevel=2)

        # ---- the single set of compiled entry points ----
        # The engine cache cycles through decode / insert endlessly, so its
        # OUTPUT sharding is pinned to the placement contract: every entry
        # point returns the cache exactly as committed at init, which (a)
        # keeps the slot layout stable across the serve lifetime and (b)
        # makes the jit cache key identical call-to-call — the decode step
        # compiles exactly once (the DESIGN.md §3 shape-stability contract
        # now extends to shardings).  Greedy tokens replicate (host-read).
        tok_sh = jax.sharding.NamedSharding(
            self.mesh, jax.sharding.PartitionSpec())
        self._prefill = jax.jit(self._prefill_fn)
        if self.paged:
            # paged signatures carry the host-managed block-table tensors;
            # the donation + out_shardings contracts are identical, so the
            # decode step still compiles exactly once per mesh
            self._decode = jax.jit(
                self._decode_fn_paged, donate_argnums=(5,),
                out_shardings=(tok_sh, self.cache_shardings))
            self._prefill_insert = jax.jit(
                self._prefill_insert_fn_paged, donate_argnums=(3,),
                out_shardings=(tok_sh, self.cache_shardings))
            # prefix-cache twin: gathers the shared-prefix blocks out of
            # the (donated) pool as dense context KV, prefills only the
            # suffix at positions [pos0, pos0+Sb), and scatters the suffix
            # rows into the table's remaining blocks.  nctx is baked into
            # the ctx_ids shape, so each (bucket, nctx) pair is one
            # compiled executable; the decode step is untouched.
            self._prefill_insert_prefix = jax.jit(
                self._prefill_insert_fn_paged_prefix, donate_argnums=(3,),
                static_argnums=(7,),       # emit: chunked prefill skips the
                out_shardings=(tok_sh, self.cache_shardings))  # lm-head
            self._insert_burst = jax.jit(
                self._insert_burst_fn_paged, donate_argnums=(0,),
                out_shardings=self.cache_shardings)
            if self.speculative is not None:
                # the two (and only two) decode-side speculative shapes:
                # the fused k-step draft scan and the k-token verify.  Same
                # donation + pinned-out_shardings contract as _decode, so
                # each compiles exactly once — with speculation on, plain
                # _decode is never traced and the decode-side executable
                # count is exactly 2 (asserted at serve warmup).
                # draft emits its (B, k) tokens directly in the verify
                # pass's spec_tokens sharding, so the host can chain
                # draft -> verify without a device round-trip (the verify
                # builds its token window on device from the draft output)
                self._spec_draft = jax.jit(
                    self._draft_fn_paged, donate_argnums=(5,),
                    out_shardings=(self._step_shardings["spec_tokens"],
                                   self.cache_shardings))
                self._spec_verify = jax.jit(
                    self._verify_fn_paged, donate_argnums=(6,),
                    out_shardings=(tok_sh, self.cache_shardings))
        else:
            self._decode = jax.jit(
                self._decode_fn, donate_argnums=(4,),
                out_shardings=(tok_sh, self.cache_shardings))
            self._prefill_insert = jax.jit(
                self._prefill_insert_fn, donate_argnums=(3,),
                out_shardings=(tok_sh, self.cache_shardings))
            self._insert_burst = jax.jit(
                self._insert_burst_fn, donate_argnums=(0,),
                out_shardings=self.cache_shardings)

        if self.paged:
            # shared one-row scatter for the device-resident block table
            # (DeviceBlockTable.device): compiles once, moves one (n_bt,)
            # row per dirty slot.  Not donated — a pipelined in-flight
            # round may still hold the previous table array as an input.
            self._bt_set_row = jax.jit(
                lambda t, s, row: t.at[s].set(row),
                out_shardings=self._step_shardings["block_table"])

        if self.decode_horizon > 1:
            # The multi-step round: same donation + pinned-out_shardings
            # contract as _decode, with the carry pinned to the decode-step
            # INPUT shardings (shr.decode_carry_specs) so round N+1 can
            # consume round N's output carry with zero resharding — the
            # round compiles exactly once per mesh and plain _decode is
            # never traced (asserted at serve warmup).
            carry_struct = {
                k: step_inputs[k]
                for k in ("token", "pos", "active", "remaining")}
            carry_sh = shr.to_shardings(
                shr.decode_carry_specs(cfg, self.mesh, carry_struct),
                self.mesh)
            if self.paged:
                self._decode_multi = jax.jit(
                    self._decode_multi_fn_paged, donate_argnums=(7,),
                    out_shardings=(tok_sh, carry_sh, self.cache_shardings))
            else:
                self._decode_multi = jax.jit(
                    self._decode_multi_fn, donate_argnums=(6,),
                    out_shardings=(tok_sh, carry_sh, self.cache_shardings))
        else:
            self._decode_multi = None

        # ---- elastic / straggler: no-op on a single-process mesh ----
        self.monitor = (StragglerMonitor(n_hosts=jax.process_count())
                        if jax.process_count() > 1 else None)

    # ------------------------------------------------------------ lifecycle
    @classmethod
    def from_devices(cls, cfg, params, *, max_batch: int, max_seq: int,
                     devices=None, model_parallel: int = 1, pods=None,
                     model=None):
        """Build on the largest valid (data, model) mesh for the available
        devices (``elastic.plan_remesh``).  One device -> the degenerate
        (1, 1) mesh: the single-device no-op path."""
        devices = list(devices if devices is not None else jax.devices())
        plan = plan_remesh(len(devices), model_parallel, pods=pods)
        mesh = make_mesh_from_plan(plan, devices)
        return cls(cfg, params, max_batch=max_batch, max_seq=max_seq,
                   mesh=mesh, model=model)

    def remesh(self, devices=None, model_parallel: int = None):
        """Elastic restart path: rebuild this Executor on the surviving
        device set; params reshard via device_put (resharding IS the load
        path, DESIGN.md §6).  Returns self when the plan already matches
        the current mesh (single-device no-op included).

        devices=None means THIS executor's devices minus any that died —
        not every visible device: an executor deliberately built on a
        submesh must not silently regrab the whole host on restart."""
        if devices is None:
            alive = set(jax.devices())
            devices = [d for d in self.mesh.devices.reshape(-1)
                       if d in alive]
        devices = list(devices)
        mp = (model_parallel if model_parallel is not None
              else self.mesh.shape.get("model", 1))
        plan = plan_remesh(len(devices), mp,
                           pods=self.mesh.shape.get("pod", None))
        if (plan.shape == tuple(self.mesh.devices.shape)
                and plan.axis_names == tuple(self.mesh.axis_names)
                and devices[:plan.n_devices]
                == list(self.mesh.devices.reshape(-1))):
            # same plan AND same physical devices: true no-op.  A same-count
            # survivor set with a swapped device (hot spare replacing a dead
            # chip) must still rebuild — that is the failure this path
            # exists for.
            return self
        mesh = make_mesh_from_plan(plan, devices)
        # Rebuild with the FULL construction config.  Regression (PR 7):
        # dropping n_blocks here silently reset a custom pool size on
        # remesh, shifting the scratch-block base (N - max_batch) under
        # live block tables; every jitted paged entry point — decode,
        # prefill_insert (+ prefix twin), burst insert, and the speculative
        # draft/verify pair — is re-created by __init__, so all of them are
        # re-pinned to the new mesh's shardings.
        return Executor(self.cfg, self.params, max_batch=self.max_batch,
                        max_seq=self.max_seq, mesh=mesh, model=self.model,
                        n_blocks=self.n_blocks if self.paged else None,
                        speculative=self.speculative,
                        decode_horizon=self.decode_horizon)

    def observe_step(self, step_times):
        """Feed per-host step times to the straggler monitor; returns its
        report, or None on the single-process no-op path."""
        if self.monitor is None:
            return None
        return self.monitor.observe(step_times)

    # ------------------------------------------------------------ jitted fns
    def _prefill_fn(self, params, tokens, true_lens, pos0=0, ctx_kv=None,
                    emit=True):
        """(B, Sb) right-padded prompts -> (first greedy token (B,), cache).

        The per-sequence cache is always dense layout; paged executors
        prefill at the bucketed extent (the rows the insert scatters into
        pool blocks), dense executors at ``max_seq`` (the slot extent).
        ``pos0``/``ctx_kv`` select the prefix-cache suffix prefill
        (DESIGN.md §3): tokens are the uncached suffix, positions start at
        ``pos0``, attention reads the shared prefix from ``ctx_kv``.
        ``emit=False`` (a chunked prefill's intermediate chunk) skips the
        lm-head and returns zero tokens — only the KV matters, and the
        output stays (B,) int32 so the jitted out_shardings contract is
        unchanged."""
        B, S = tokens.shape
        batch = {"tokens": tokens}
        if self.cfg.rope == "mrope":
            pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
            batch["positions"] = jnp.broadcast_to(pos[:, None], (B, 3, S))
        if self.cfg.family == "encdec":
            batch["frames"] = jnp.zeros(
                (B, self.cfg.enc_frames, self.cfg.d_model), self.dtype)
        logits, cache = self.model.prefill(
            params, batch, cache_len=(None if self.paged else self.max_seq),
            true_lens=true_lens, pos0=pos0, ctx_kv=ctx_kv, emit_logits=emit)
        if not emit:
            return jnp.zeros((B,), jnp.int32), cache
        return jnp.argmax(logits, -1).astype(jnp.int32), cache

    def _decode_fn(self, params, token, pos, active, cache):
        """One masked decode step over all slots; greedy next token (B,)."""
        batch = {"token": token, "pos": pos, "active": active}
        if self.cfg.rope == "mrope":
            batch["positions"] = jnp.broadcast_to(
                pos[:, None, :], (pos.shape[0], 3, 1))
        logits, cache = self.model.decode_step(params, batch, cache,
                                               mesh=self.mesh)
        return jnp.argmax(logits, -1).astype(jnp.int32), cache

    def _decode_fn_paged(self, params, token, pos, active, block_table,
                         cache):
        """Paged twin of ``_decode_fn``: the (B, n_bt) block table is a
        decode-step INPUT (host-allocated, DESIGN.md §3), not cache state —
        so the donated cache tree and its pinned out_shardings are
        unchanged step-to-step and the step compiles exactly once."""
        batch = {"token": token, "pos": pos, "active": active,
                 "block_table": block_table}
        if self.cfg.rope == "mrope":
            batch["positions"] = jnp.broadcast_to(
                pos[:, None, :], (pos.shape[0], 3, 1))
        logits, cache = self.model.decode_step(params, batch, cache,
                                               mesh=self.mesh)
        return jnp.argmax(logits, -1).astype(jnp.int32), cache

    def _decode_multi_fn(self, params, token, pos, active, remaining,
                         eos_id, cache):
        """Horizon-M on-device decode round (dense layout): M masked decode
        steps under one dispatch, EOS/max-new retirement applied in-kernel
        (``Model.decode_scan``).  Returns ((M, B) raw step tokens, the
        final carry in decode-input shardings, cache)."""
        batch = {"token": token, "pos": pos, "active": active,
                 "remaining": remaining, "eos_id": eos_id}
        toks, carry, cache = self.model.decode_scan(
            params, batch, cache, self.decode_horizon, mesh=self.mesh)
        return toks, carry, cache

    def _decode_multi_fn_paged(self, params, token, pos, active, remaining,
                               eos_id, block_table, cache):
        """Paged twin of ``_decode_multi_fn``; the block table is
        scan-invariant (the host pre-allocates the round's span — same
        contract as the speculative draft scan)."""
        batch = {"token": token, "pos": pos, "active": active,
                 "remaining": remaining, "eos_id": eos_id,
                 "block_table": block_table}
        toks, carry, cache = self.model.decode_scan(
            params, batch, cache, self.decode_horizon, mesh=self.mesh)
        return toks, carry, cache

    def _draft_fn_paged(self, params, token, pos, active, block_table,
                        cache):
        """Fused k-step DRAFT pass (DESIGN.md §"Self-speculative decoding"):
        ``lax.scan`` over the standard decode body with the low-bit draft
        params — one device dispatch drafts all k tokens, writing
        draft-computed KV at positions [pos, pos+k) (the verify pass
        re-scatters target KV over the same entries).  The block table is
        scan-invariant: the host pre-allocates every block the round can
        touch before calling.  Returns ((B, k) greedy drafts, cache)."""
        def step(carry, _):
            tok, p, kv = carry
            batch = {"token": tok, "pos": p, "active": active,
                     "block_table": block_table}
            logits, kv = self.model.decode_step(params, batch, kv,
                                                mesh=self.mesh)
            nxt = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
            return (nxt, p + 1, kv), nxt[:, 0]

        (_, _, cache), toks = jax.lax.scan(
            step, (token, pos, cache), None, length=self.spec_k)
        return jnp.moveaxis(toks, 0, 1), cache          # (B, k)

    def _verify_fn_paged(self, params, token, drafts, pos0, active,
                         block_table, cache):
        """k-token VERIFY at the target width: one decode-shaped batched
        pass (M = B*k rows through the same routed paged-attention kernel)
        over the feed token followed by the first k-1 drafts — the window
        is built ON DEVICE from the draft pass's output, so the host can
        enqueue draft and verify back-to-back without syncing the drafts
        in between.  Returns ((B, k) greedy verdicts, cache) — verdict j is
        the target's next token after consuming tokens[:, :j+1]."""
        tokens = jnp.concatenate([token, drafts[:, :self.spec_k - 1]],
                                 axis=1)
        logits, cache = self.model.verify_step(
            params, {"tokens": tokens, "pos0": pos0, "active": active,
                     "block_table": block_table}, cache, mesh=self.mesh)
        return jnp.argmax(logits, -1).astype(jnp.int32), cache

    def _prefill_insert_fn(self, params, tokens, true_lens, cache, slot):
        """Fused single-admission path: prefill one sequence and write its
        cache straight into ``slot``."""
        first, seq_cache = self._prefill_fn(params, tokens, true_lens)
        return first, self.model.insert_cache(cache, seq_cache, slot)

    def _prefill_insert_fn_paged(self, params, tokens, true_lens, cache,
                                 slot, block_row):
        first, seq_cache = self._prefill_fn(params, tokens, true_lens)
        return first, self.model.insert_cache(cache, seq_cache, slot,
                                              block_row=block_row)

    def _prefill_insert_fn_paged_prefix(self, params, tokens, true_lens,
                                        cache, slot, block_row, ctx_ids,
                                        emit=True):
        """Prefix-cache suffix prefill (DESIGN.md §3): ``ctx_ids`` (nctx,)
        names the shared-prefix pool blocks (absolute positions
        ``[0, nctx*bs)``), ``tokens`` holds only the uncached suffix, and
        ``block_row`` is the slot's FULL table row — the suffix rows
        scatter into its entries from logical block ``nctx`` on.  Reading
        the context out of ``cache`` before the insert writes it is safe
        under donation (one jitted program).  ``emit`` is static (jit
        static_argnums): an intermediate chunk of a chunked prefill passes
        False and skips the lm-head — the same entry point also serves
        chunk insertion, with ``ctx_ids`` naming the blocks of chunks
        0..N-1 (DESIGN.md §3 "SLO scheduling")."""
        nctx = ctx_ids.shape[0]                     # static, from the shape
        pos0 = nctx * self.block_size
        ctx_kv = (self.model.gather_prefix_ctx(cache, ctx_ids, self.dtype)
                  if nctx else None)
        first, seq_cache = self._prefill_fn(params, tokens, true_lens,
                                            pos0=pos0, ctx_kv=ctx_kv,
                                            emit=emit)
        return first, self.model.insert_cache(cache, seq_cache, slot,
                                              block_row=block_row[nctx:])

    def _insert_burst_fn(self, cache, seq_cache, slots, valid):
        """Insert row i of ``seq_cache`` into slot ``slots[i]`` for every i
        with ``valid[i]`` (both (max_batch,), traced)."""
        for i in range(self.max_batch):
            row = self.model.slice_cache(seq_cache, jnp.int32(i))
            updated = self.model.insert_cache(cache, row, slots[i])
            cache = jax.tree_util.tree_map(
                lambda new, old, i=i: jnp.where(valid[i], new, old),
                updated, cache)
        return cache

    def _insert_burst_fn_paged(self, cache, seq_cache, slots, valid,
                               block_rows):
        """Paged burst: scatter row i of the dense prefill output into the
        blocks of ``block_rows[i]`` ((max_batch, n_bt), traced)."""
        for i in range(self.max_batch):
            row = self.model.slice_cache(seq_cache, jnp.int32(i))
            updated = self.model.insert_cache(cache, row, slots[i],
                                              block_row=block_rows[i])
            cache = jax.tree_util.tree_map(
                lambda new, old, i=i: jnp.where(valid[i], new, old),
                updated, cache)
        return cache

    # ---------------------------------------------------------- entry points
    def _init_cache_fn(self):
        return self.model.init_cache(
            self.max_batch, self.max_seq, dtype=self.dtype,
            layout=self.layout,
            block_size=self.block_size or None,
            n_blocks=self.n_blocks if self.paged else None)

    def init_cache(self):
        """The engine's batched decode cache, committed slot-over-data
        (dense) / block-over-data (paged) at birth (placement happens
        inside ``Model.init_cache(mesh=...)``)."""
        return self.model.init_cache(
            self.max_batch, self.max_seq, dtype=self.dtype, mesh=self.mesh,
            layout=self.layout, block_size=self.block_size or None,
            n_blocks=self.n_blocks if self.paged else None)

    def prefill(self, tokens, true_lens):
        return self._prefill(self.params, jnp.asarray(tokens),
                             jnp.asarray(true_lens))

    def prefill_insert(self, tokens, true_lens, cache, slot: int,
                       block_row=None, ctx_ids=None, emit=True):
        """Fused prefill + slot insert.  ``ctx_ids`` (prefix-cache /
        chunked-prefill mode, paged only) routes to the suffix-prefill
        twin: pass the context block ids — possibly empty, which compiles
        its own nctx=0 shape but computes the identical graph — and
        ``tokens`` holding only the uncached suffix / current chunk.
        ``emit=False`` (intermediate chunks; ctx path only) skips the
        lm-head and returns zero tokens."""
        if self.paged and ctx_ids is not None:
            return self._prefill_insert_prefix(
                self.params, jnp.asarray(tokens), jnp.asarray(true_lens),
                cache, jnp.int32(slot), jnp.asarray(block_row),
                jnp.asarray(ctx_ids, jnp.int32), emit)
        if not emit:
            raise ValueError("emit=False needs the ctx (prefix/chunk) "
                             "prefill path — pass ctx_ids")
        if self.paged:
            return self._prefill_insert(self.params, jnp.asarray(tokens),
                                        jnp.asarray(true_lens), cache,
                                        jnp.int32(slot),
                                        jnp.asarray(block_row))
        return self._prefill_insert(self.params, jnp.asarray(tokens),
                                    jnp.asarray(true_lens), cache,
                                    jnp.int32(slot))

    def insert_burst(self, cache, seq_cache, slots, valid, block_rows=None):
        if self.paged:
            return self._insert_burst(cache, seq_cache, jnp.asarray(slots),
                                      jnp.asarray(valid),
                                      jnp.asarray(block_rows))
        return self._insert_burst(cache, seq_cache, jnp.asarray(slots),
                                  jnp.asarray(valid))

    def make_block_table(self) -> DeviceBlockTable:
        """A host-mirrored device-resident block table for this executor
        (paged only).  The serve loop writes the host mirror like a plain
        ndarray; decode dispatches reuse the committed device copy and pay
        only incremental row scatters for slots that changed."""
        return DeviceBlockTable(self)

    def _device_table(self, block_table):
        """The block table as a committed device array: a
        :class:`DeviceBlockTable` serves its cached copy (zero transfer
        when unchanged); a raw host array takes the legacy full upload."""
        if isinstance(block_table, DeviceBlockTable):
            return block_table.device()
        return jax.device_put(jnp.asarray(block_table),
                              self._step_shardings["block_table"])

    def decode(self, token, pos, active, cache, block_table=None):
        """One decode step; inputs are committed slot-over-data so jit
        compiles the distributed step (computation follows data).  One
        tree-level device_put moves the host step inputs in a single
        transfer — this runs once per generated token; the block table
        rides the :meth:`_device_table` cache."""
        put = {"token": jnp.asarray(token), "pos": jnp.asarray(pos),
               "active": jnp.asarray(active)}
        put = jax.device_put(
            put, {k: self._step_shardings[k] for k in put})
        if self.paged:
            return self._decode(self.params, put["token"], put["pos"],
                                put["active"],
                                self._device_table(block_table), cache)
        return self._decode(self.params, put["token"], put["pos"],
                            put["active"], cache)

    def decode_multi(self, token, pos, active, remaining, cache,
                     block_table=None, eos_id: int = -1):
        """One horizon-M decode ROUND (requires ``decode_horizon > 1``).

        ``token``/``pos``/``active``/``remaining`` may be host arrays (the
        rebuild path after the host mutated its mirrors) or the device
        carry dict returned by the previous round — device_put against the
        identical shardings is a no-op for already-committed leaves, so
        chaining rounds moves zero carry bytes.  ``eos_id`` is a TRACED
        scalar (value changes never recompile); -1 disables EOS retirement.
        Returns ((M, B) raw step tokens — replicated for the host sync,
        carry dict, cache)."""
        if self._decode_multi is None:
            raise ValueError("decode_multi needs decode_horizon > 1 at "
                             "construction")
        put = {"token": jnp.asarray(token), "pos": jnp.asarray(pos),
               "active": jnp.asarray(active),
               "remaining": jnp.asarray(remaining, jnp.int32)}
        put = jax.device_put(
            put, {k: self._step_shardings[k] for k in put})
        eos = jnp.int32(eos_id)
        if self.paged:
            return self._decode_multi(
                self.params, put["token"], put["pos"], put["active"],
                put["remaining"], eos, self._device_table(block_table),
                cache)
        return self._decode_multi(
            self.params, put["token"], put["pos"], put["active"],
            put["remaining"], eos, cache)

    def draft(self, token, pos, active, cache, block_table):
        """One fused k-step draft pass with the low-bit view of the serving
        checkpoint.  Same input contract as :meth:`decode`; returns
        ((B, k) draft tokens, cache)."""
        put = {"token": jnp.asarray(token), "pos": jnp.asarray(pos),
               "active": jnp.asarray(active)}
        put = jax.device_put(
            put, {k: self._step_shardings[k] for k in put})
        return self._spec_draft(self.draft_params, put["token"], put["pos"],
                                put["active"],
                                self._device_table(block_table), cache)

    def verify(self, token, drafts, pos0, active, cache, block_table):
        """One k-token verify pass at the target width.  ``token`` (B, 1)
        is the round's feed token, ``drafts`` (B, k) the draft pass's
        output (device array or host) — the verify window [token,
        drafts[:, :k-1]] is assembled on device, so passing the DeviceArray
        straight from :meth:`draft` chains the two dispatches without a
        host sync.  ``pos0`` (B, 1) is the feed position.  Returns
        ((B, k) target verdicts, cache)."""
        put = {"token": jnp.asarray(token),
               "spec_tokens": jnp.asarray(drafts),
               "pos": jnp.asarray(pos0), "active": jnp.asarray(active)}
        put = jax.device_put(
            put, {k: self._step_shardings[k] for k in put})
        return self._spec_verify(self.params, put["token"],
                                 put["spec_tokens"], put["pos"],
                                 put["active"],
                                 self._device_table(block_table), cache)

    # jit-cache introspection for the shape-stability tests / stats
    def decode_cache_size(self) -> int:
        # _cache_size is a private jax API; degrade to -1 (unknown) rather
        # than fail the stats path if an upgrade removes it.
        return getattr(self._decode, "_cache_size", lambda: -1)()

    def prefill_cache_sizes(self) -> dict:
        """Compiled-shape counts per prefill path (the warmup log / the
        warmup reachability test): burst prefill, fused prefill+insert,
        burst insert."""
        sz = lambda f: getattr(f, "_cache_size", lambda: -1)()
        out = {"prefill": sz(self._prefill),
               "prefill_insert": sz(self._prefill_insert),
               "insert_burst": sz(self._insert_burst)}
        if self.paged:
            out["prefill_insert_prefix"] = sz(self._prefill_insert_prefix)
        return out

    def spec_cache_sizes(self) -> dict:
        """Compiled decode-side executable counts under speculation: the
        compile-once contract becomes compile-exactly-TWICE — one draft
        scan + one verify shape, and the plain decode step never traces
        (``decode == 0``).  Asserted at serve warmup."""
        sz = lambda f: getattr(f, "_cache_size", lambda: -1)()
        return {"draft": sz(self._spec_draft),
                "verify": sz(self._spec_verify),
                "decode": sz(self._decode)}

    def decode_multi_cache_size(self) -> int:
        """Compiled executable count of the horizon-M round (the
        compile-once contract at the round shape)."""
        if self._decode_multi is None:
            return 0
        return getattr(self._decode_multi, "_cache_size", lambda: -1)()

    def multi_cache_sizes(self) -> dict:
        """Decode-side executable counts under a horizon > 1: exactly one
        round shape, and the single-step twin never traces.  Asserted at
        serve warmup (the multi-step entry in the warmup ladder)."""
        return {"decode_multi": self.decode_multi_cache_size(),
                "decode": self.decode_cache_size()}
