"""Partition rules: map every parameter / batch / cache leaf to a
PartitionSpec for the production mesh (DESIGN.md §5).

Axes: ``("data", "model")`` single-pod, ``("pod", "data", "model")`` multi-pod
("pod" joins "data" as an outer data-parallel / FSDP axis).

Serving: weights TP over "model", replicated over data; batch over "data".
Training: FSDP — the non-TP weight dim is additionally sharded over the
data axes (ZeRO-3 semantics under GSPMD: all-gather on use, reduce-scatter
on grad), optimizer moments inherit the param spec.

Rules are *logical*: a rule names the spec of the trailing (weight) dims;
leading layer-stack dims are automatically None.  Quantized serving leaves
(``QuantizedTensor``: codes-or-planes + scale, format as static metadata)
derive their spec from the same logical rule — dispatch is typed, never
dict-key sniffing.
"""
from __future__ import annotations

import re
from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.psi import QuantizedTensor
from repro.models.kvcache import KVCache

FSDP_AXIS = "data"
DP_AXES = ("pod", "data")        # outer batch axes when present

# Models narrower than this gain nothing from 16-way tensor parallelism —
# per-shard GEMMs degenerate (d_ff/16 < MXU tile) and every layer pays two
# all-reduces.  Below the threshold the "model" axis is repurposed as extra
# data/sequence parallelism and weights replicate (whisper-base: 70 MB).
TP_MIN_D_MODEL = 1024


def tp_enabled(cfg) -> bool:
    return cfg.d_model >= TP_MIN_D_MODEL


def _dp(mesh: Mesh, cfg=None) -> Tuple:
    """Data-parallel axes present in this mesh (flattened for batch dim).
    When TP is disabled for this arch, "model" joins the batch axes."""
    axes = tuple(a for a in DP_AXES if a in mesh.axis_names)
    if cfg is not None and not tp_enabled(cfg) and "model" in mesh.axis_names:
        axes = axes + ("model",)
    return axes


def _divisible(n: int, mesh: Mesh, axes) -> bool:
    if not axes:
        return False
    size = 1
    for a in (axes if isinstance(axes, tuple) else (axes,)):
        size *= mesh.shape[a]
    return n % size == 0


def _pick_batch_axes(B: int, mesh: Mesh, dp):
    """Largest prefix of the data-parallel axes that divides the batch/slot
    dim ``B`` (falls back to any single axis, then replication).  One shared
    decision for batch inputs, decode-slot tensors, and the KV cache, so they
    stay co-sharded."""
    if _divisible(B, mesh, dp):
        return dp
    for k in range(len(dp) - 1, 0, -1):
        if _divisible(B, mesh, dp[:k]):
            return dp[:k]
    return next((a for a in dp if B % mesh.shape[a] == 0), None)


def batch_shard_count(cfg, mesh: Mesh, B: int) -> int:
    """How many ways the slot/batch dim of serving tensors splits on this
    mesh — the number of per-shard slot pools the scheduler partitions over
    (1 on a single-device mesh: the no-op path)."""
    bax = _pick_batch_axes(B, mesh, _dp(mesh, cfg))
    if bax is None:
        return 1
    axes = bax if isinstance(bax, tuple) else (bax,)
    return int(np.prod([mesh.shape[a] for a in axes]))


def slot_shard_map(cfg, mesh: Mesh, n_slots: int) -> np.ndarray:
    """slot -> data-shard index under GSPMD's contiguous-chunk layout for a
    ``(n_slots, ...)`` leaf sharded over the data axes (shard i holds slots
    [i*n/d, (i+1)*n/d)).  The mesh-aware SlotAllocator uses this to admit
    into per-shard free slots (DESIGN.md §5)."""
    d = batch_shard_count(cfg, mesh, n_slots)
    return (np.arange(n_slots) * d) // n_slots


def block_shard_map(cfg, mesh: Mesh, n_total: int,
                    n_usable: int = None) -> np.ndarray:
    """block id -> data-shard index for a paged pool whose leading dim is
    ``n_total`` blocks (``n_blocks`` usable + per-slot scratch — the WHOLE
    dim is what GSPMD chunks over the data axes, so the map must be
    computed against it).  Returns the map truncated to the ``n_usable``
    allocatable ids the scheduler's BlockAllocator partitions over
    (DESIGN.md §5); scratch blocks land on whatever shard the chunking
    gives them and are never allocated."""
    d = batch_shard_count(cfg, mesh, n_total)
    full = (np.arange(n_total) * d) // n_total
    return full[:n_usable if n_usable is not None else n_total]


# ---------------------------------------------------------------------------
# Logical rules: (path regex) -> trailing-dims spec builder.
# Specs use "model" for TP and "fsdp" as a placeholder replaced by the data
# axes in training mode / None in serving mode.
# ---------------------------------------------------------------------------
_RULES = (
    # embeddings: vocab over model (model-parallel logits); d replicated —
    # FSDP-sharding the gather output dim provokes involuntary remat in the
    # SPMD partitioner (resharding a gather across the batch axes).
    (r"(^|/)embed$",        ("model", None)),
    (r"(^|/)lm_head$",      (None, "model")),
    # attention
    (r"(^|/)wq$",           ("fsdp", "model")),
    (r"(^|/)wk$",           ("fsdp", "model")),
    (r"(^|/)wv$",           ("fsdp", "model")),
    (r"(^|/)wo$",           ("model", "fsdp")),
    # dense mlp
    (r"(^|/)w_gate$",       ("fsdp", "model")),   # moe experts override below
    (r"(^|/)w_up$",         ("fsdp", "model")),
    (r"(^|/)w_down$",       ("model", "fsdp")),
    # rg-lru
    (r"(^|/)w_in_rec$",     ("fsdp", "model")),
    (r"(^|/)w_in_gate$",    ("fsdp", "model")),
    (r"(^|/)rglru_wa$",     ("fsdp", "model")),
    (r"(^|/)rglru_wx$",     ("fsdp", "model")),
    (r"(^|/)rglru_(ba|bx|lambda)$", ("model",)),
    (r"(^|/)w_out$",        ("model", "fsdp")),
    # mamba
    (r"(^|/)in_proj$",      ("fsdp", "model")),
    (r"(^|/)x_proj$",       ("model", "fsdp")),
    (r"(^|/)dt_proj_w$",    ("fsdp", "model")),
    (r"(^|/)dt_proj_b$",    ("model",)),
    (r"(^|/)out_proj$",     ("model", "fsdp")),
    (r"(^|/)a_log$",        ("model", None)),
    (r"(^|/)d_skip$",       ("model",)),
    (r"(^|/)conv1d_w$",     (None, "model")),
    (r"(^|/)conv1d_b$",     ("model",)),
    # moe router
    (r"(^|/)router$",       ("fsdp", None)),
)

_MOE_EP = {  # experts divide the model axis: expert parallelism
    r"(^|/)w_gate$": ("model", "fsdp", None),
    r"(^|/)w_up$":   ("model", "fsdp", None),
    r"(^|/)w_down$": ("model", None, "fsdp"),
}
_MOE_TP = {  # tensor parallelism inside each expert
    r"(^|/)w_gate$": (None, "fsdp", "model"),
    r"(^|/)w_up$":   (None, "fsdp", "model"),
    r"(^|/)w_down$": (None, "model", "fsdp"),
}


def _path_str(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)


def _logical_spec(path: str, cfg, mesh: Mesh) -> Optional[Tuple]:
    if cfg.n_experts and re.search(r"moe/", path):
        table = (_MOE_EP if _divisible(cfg.n_experts, mesh, "model")
                 else _MOE_TP)
        for pat, spec in table.items():
            if re.search(pat, path):
                return spec
    for pat, spec in _RULES:
        if re.search(pat, path):
            return spec
    return None                       # norms, misc small params -> replicate


def _materialize(spec_tail, leaf_shape, mesh: Mesh, mode: str,
                 use_tp: bool = True):
    """Map a logical trailing spec onto a concrete leaf shape; leading dims
    (layer stacks) replicate.  'fsdp' resolves to the data axes in train
    mode, None otherwise.  Axes that don't divide the dim are dropped."""
    fsdp = tuple(a for a in DP_AXES if a in mesh.axis_names) if mode == "train" else None
    tail = []
    for dim, ax in zip(leaf_shape[-len(spec_tail):], spec_tail):
        if ax == "model" and not use_tp:
            ax = None
        if ax == "fsdp":
            ax = fsdp
        if ax is None:
            tail.append(None)
            continue
        if not _divisible(dim, mesh, ax):
            # fall back: try a single axis out of a tuple, else replicate
            if isinstance(ax, tuple):
                ax = next((a for a in ax if dim % mesh.shape[a] == 0), None)
                tail.append(ax)
            else:
                tail.append(None)
            continue
        tail.append(ax)
    lead = [None] * (len(leaf_shape) - len(spec_tail))
    return P(*(lead + tail))


def _spec_for_qt(leaf: QuantizedTensor, spec_tail, mesh: Mesh, mode: str,
                 use_tp: bool = True) -> QuantizedTensor:
    """QuantizedTensor leaf: unpacked codes keep the weight spec; packed
    planes prepend a replicated bit-plane dim; scale shards only its
    non-singleton dims.  Returns a QuantizedTensor *of specs* (same static
    format metadata), so spec trees and param trees stay structure-equal for
    device_put / out_shardings."""
    data_tail = ((None,) + tuple(spec_tail)) if leaf.packed else spec_tail
    data = _materialize(data_tail, leaf.data.shape, mesh, mode, use_tp)
    sc = leaf.scale.shape
    sc_tail = [ax if sc[-len(spec_tail) + i] > 1 else None
               for i, ax in enumerate(spec_tail)]
    scale = _materialize(tuple(sc_tail), sc, mesh, mode, use_tp)
    return QuantizedTensor(data, scale, leaf.fmt, leaf.packed)


def _is_qt(x):
    return isinstance(x, QuantizedTensor)


def param_specs(params, cfg, mesh: Mesh, mode: str = "serve"):
    """PartitionSpec pytree matching ``params`` (plain or PSI-quantized)."""
    use_tp = tp_enabled(cfg)
    if not use_tp:
        # Small model: replicate everything (whisper-base: 70 MB of weights);
        # the mesh axes all become batch parallelism.  Mixing FSDP shards
        # with >16-way batch sharding provokes involuntary rematerialization
        # in the SPMD partitioner (observed: 217 GB replicated logits).
        def repl(leaf):
            if _is_qt(leaf):
                return QuantizedTensor(P(), P(), leaf.fmt, leaf.packed)
            return P()
        return jax.tree_util.tree_map(repl, params, is_leaf=_is_qt)

    def one(path, leaf):
        p = _path_str(path)
        spec_tail = _logical_spec(p, cfg, mesh)
        if _is_qt(leaf):
            if spec_tail is None:
                return QuantizedTensor(P(), P(), leaf.fmt, leaf.packed)
            return _spec_for_qt(leaf, spec_tail, mesh, mode, use_tp)
        if spec_tail is None or leaf.ndim < len(spec_tail):
            return P()
        return _materialize(spec_tail, leaf.shape, mesh, mode, use_tp)

    return jax.tree_util.tree_map_with_path(one, params, is_leaf=_is_qt)


def batch_specs(cfg, mesh: Mesh, batch_tree, seq_shard: bool = False):
    """Input batch: batch dim over the data axes (replicated if indivisible,
    e.g. long_500k's batch=1).  When TP is off for this arch, "model" joins
    the batch axes; if the batch still can't use it, the token sequence dim
    is sharded over "model" instead (sequence parallelism)."""
    dp = _dp(mesh, cfg)
    free_model = (not tp_enabled(cfg)) and "model" in mesh.axis_names

    def pick_bax(B):
        return _pick_batch_axes(B, mesh, dp)

    def one(path, leaf):
        name = _path_str(path)
        if leaf.ndim == 0:
            return P()
        B = leaf.shape[0]
        bax = pick_bax(B)
        spec = [bax] + [None] * (leaf.ndim - 1)
        used = set()
        for a in (bax if isinstance(bax, tuple) else (bax,) if bax else ()):
            used.add(a)
        if name == "tokens" and leaf.ndim >= 2:
            S = leaf.shape[1]
            if free_model and "model" not in used and S % mesh.shape["model"] == 0:
                spec[1] = "model"           # sequence parallelism
            elif seq_shard and bax is None and _divisible(S, mesh, "data"):
                spec[1] = "data"
        return P(*spec)

    return jax.tree_util.tree_map_with_path(one, batch_tree)


def _kv_layout(cfg, mesh: Mesh, B, C, Hkv):
    """(batch_ax, seq_ax, head_ax) for KV cache tensors — one decision
    shared by k, v, and k_pos so masks stay co-sharded with values."""
    bax = _pick_batch_axes(B, mesh, _dp(mesh, cfg))
    used = set(bax if isinstance(bax, tuple) else (bax,) if bax else ())
    use_tp = tp_enabled(cfg)
    head_ax = "model" if (use_tp and Hkv % mesh.shape["model"] == 0) else None
    seq_ax = None
    if head_ax is None:
        # heads unshardable (MQA/GQA < TP degree): shard the KV ring dim
        # over whichever axis is free — "model" first (it is otherwise
        # idle for this tensor), then "data" (long_500k's batch=1).
        cand = ("model", "data") if "model" in mesh.axis_names else ("data",)
        for a in cand:
            if a not in used and C % mesh.shape[a] == 0:
                seq_ax = a
                break
    return bax, seq_ax, head_ax


def _serve_leaf_spec(cfg, mesh: Mesh, name: str, shape, paged=False) -> P:
    """Spec for one BLOCK-LEVEL cache leaf (batch/slot dim on axis 0).
    This is the core rule table; ``cache_specs`` prepends the layer-group
    dim for stacked leaves, and ``block_cache_specs`` applies it verbatim
    inside the decode scan (masked writes stay on-shard).
    Block-level leaf shapes (dense layout):
      attn k/v:   (B, C, Hkv, hd)   k/v_scale: (B, C, Hkv, 1)
      k_pos:      (B, C)
      mamba ssm:  (B, di, N)   conv: (B, cw-1, di)
      rglru h:    (B, dr)      conv: (B, cw-1, dr)
      enc_out:    (B, F, d)
    Paged layout (``paged=True``): pool leaves (N_total, bs, Hkv, hd) /
    scale (N_total, bs, Hkv, 1) — the BLOCK dim shards over the data axes
    (the allocator follows ``block_shard_map``, replacing the contiguous
    slot-chunk assumption), heads over "model" when divisible, and the
    in-block position dim stays replicated (a block is the indivisible
    transfer unit).
    """
    use_tp = tp_enabled(cfg)
    B = shape[0]
    spec = [None] * len(shape)
    spec[0] = _pick_batch_axes(B, mesh, _dp(mesh, cfg))
    if paged:
        if (len(shape) == 4 and use_tp
                and shape[2] % mesh.shape.get("model", 1) == 0):
            spec[2] = "model"
        return P(*spec)
    if name.endswith("enc_out"):
        return P(*spec)
    if re.search(r"(^|/)k$|(^|/)v$|k_scale$|v_scale$", name) and len(shape) == 4:
        spec[0], spec[1], spec[2] = _kv_layout(cfg, mesh, B, shape[1],
                                               max(cfg.n_kv_heads, 1))
        if shape[2] % mesh.shape.get("model", 1) != 0 and spec[2]:
            spec[2] = None
    elif re.search(r"k_pos", name) and len(shape) == 2:
        # same layout decision as k/v (real kv-head count matters)
        spec[0], spec[1], _ = _kv_layout(cfg, mesh, B, shape[1],
                                         max(cfg.n_kv_heads, 1))
    elif re.search(r"ssm$", name) and len(shape) == 3:
        if use_tp and _divisible(shape[1], mesh, "model"):
            spec[1] = "model"
    elif re.search(r"conv$", name) and len(shape) == 3:
        if use_tp and _divisible(shape[2], mesh, "model"):
            spec[2] = "model"
    elif re.search(r"(^|/)h$", name) and len(shape) == 2:
        if use_tp and _divisible(shape[1], mesh, "model"):
            spec[1] = "model"
    return P(*spec)


def cache_specs(cfg, mesh: Mesh, cache_tree, seq_shard: bool = False):
    """Decode cache: batch/slot (or paged block-pool) dim over the data
    axes; KV seq (ring) dim over "data" when the batch can't use it
    (long_500k); mamba/rg-lru channel state over "model"; KV heads over
    "model" only when divisible (MQA/GQA: replicate).  Stack leaves carry
    the layer-group dim first (always replicated); the per-leaf rules live
    in ``_serve_leaf_spec``.

    Accepts either a typed :class:`KVCache` — the layout is read off its
    static metadata and a structure-equal KVCache *of specs* is returned
    (the QuantizedTensor-of-specs pattern, so device_put / out_shardings
    see matching trees) — or a bare kv stack tree (dense rules).
    """
    if isinstance(cache_tree, KVCache):
        kv = _kv_tree_specs(cfg, mesh, cache_tree.kv, cache_tree.paged)
        enc = (None if cache_tree.enc_out is None else _serve_leaf_spec(
            cfg, mesh, "enc_out", cache_tree.enc_out.shape))
        return cache_tree.replace(kv=kv, enc_out=enc)
    return _kv_tree_specs(cfg, mesh, cache_tree, paged=False)


def _kv_tree_specs(cfg, mesh: Mesh, kv_tree, paged: bool):
    def one(path, leaf):
        name = _path_str(path)
        if leaf.ndim == 0:
            return P()
        if re.search(r"(^|/)b\d+/", name):
            # scanned group leaf: replicated layer-group dim leads
            return P(None, *_serve_leaf_spec(cfg, mesh, name, leaf.shape[1:],
                                             paged))
        # enc_out / unrolled tail-block leaves: batch is axis 0 already
        return _serve_leaf_spec(cfg, mesh, name, leaf.shape, paged)

    return jax.tree_util.tree_map_with_path(one, kv_tree)


def block_cache_specs(cfg, mesh: Mesh, block_tree, paged: bool = False):
    """Specs for one block's cache dict as seen INSIDE the decode scan
    (no leading group dim).  Used by the masked-write constraint the
    executor threads through ``Model.decode_step`` (DESIGN.md §5)."""
    def one(path, leaf):
        name = _path_str(path)
        if leaf.ndim == 0:
            return P()
        return _serve_leaf_spec(cfg, mesh, name, leaf.shape, paged)

    return jax.tree_util.tree_map_with_path(one, block_tree)


def constrain_block_cache(cfg, mesh: Mesh, block_tree, paged: bool = False):
    """with_sharding_constraint over one block's cache dict (decode scan
    body): pins the masked scatter writes to the slot-over-data (dense) or
    block-over-data (paged) layout so the SPMD partitioner cannot fall back
    to replicate-and-gather.  The executor threads this through
    ``Model.decode_step`` -> transformer -> attention; it is a no-op on a
    single-device mesh."""
    specs = block_cache_specs(cfg, mesh, block_tree, paged)
    return jax.tree_util.tree_map(
        lambda leaf, s: jax.lax.with_sharding_constraint(
            leaf, NamedSharding(mesh, s)),
        block_tree, specs)


def serve_batch_specs(cfg, mesh: Mesh, batch_tree):
    """Decode-step inputs (token (B, 1), pos (B, 1) / positions (B, 3, 1),
    active (B,)): slot dim over the data axes, everything else replicated —
    co-sharded with the slot dim of the decode cache."""
    def one(leaf):
        if getattr(leaf, "ndim", 0) == 0:
            return P()
        bax = _pick_batch_axes(leaf.shape[0], mesh, _dp(mesh, cfg))
        return P(bax, *([None] * (leaf.ndim - 1)))

    return jax.tree_util.tree_map(one, batch_tree)


def decode_carry_specs(cfg, mesh: Mesh, carry_tree):
    """Specs for the multi-step decode carry (token / pos / active /
    remaining, DESIGN.md §3 "Multi-step decode & host overlap").  The carry
    chains rounds device-side — round N+1 consumes round N's output carry
    directly — so its out_shardings MUST equal the decode-step input
    shardings leaf-for-leaf, or every round boundary would reshard.  The
    rule is therefore exactly :func:`serve_batch_specs` (slot dim over the
    data axes); this wrapper exists to make that invariant a named API
    instead of a coincidence."""
    return serve_batch_specs(cfg, mesh, carry_tree)


def to_shardings(spec_tree, mesh: Mesh):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))
