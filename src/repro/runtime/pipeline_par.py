"""Optional GPipe-style pipeline parallelism over a "stage" mesh axis.

The assigned production mesh has no stage axis (DP x TP covers the 40-cell
dry-run), but at >=1000-node scale cross-pod TP is infeasible and PP becomes
the inter-pod axis.  This module implements the classic microbatch-rotation
schedule with ``shard_map`` + ``jax.lax.ppermute``:

  * layers are split into S contiguous stages; stage s holds its slice of the
    layer-stacked params (shard over the stage axis — no replication);
  * the microbatch "belt" rotates activations stage->stage+1 each tick;
  * S warmup + S cooldown bubbles, standard GPipe efficiency M/(M+S-1).

Tested on a forced-8-device CPU mesh in tests/test_distributed.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def pipeline_apply(layer_fn, params_stacked, x_microbatches, mesh: Mesh,
                   stage_axis: str = "stage"):
    """Run ``y = layer_fn(p_layer, x)`` through a pipeline.

    params_stacked: pytree with leading dim L (= S * layers_per_stage).
    x_microbatches: (M, mb, ...) — M microbatches.
    Returns (M, mb, ...) outputs, pipelined over the ``stage_axis`` of mesh.
    """
    S = mesh.shape[stage_axis]
    M = x_microbatches.shape[0]
    L = jax.tree_util.tree_leaves(params_stacked)[0].shape[0]
    assert L % S == 0, (L, S)
    per_stage = L // S

    def stage_fn(p_stage, xs):
        # p_stage: (per_stage, ...) slice on this stage; xs: (M, mb, ...)
        def run_stage(x):
            def body(h, pl):
                return layer_fn(pl, h), None
            h, _ = jax.lax.scan(body, x, p_stage)
            return h

        stage_id = jax.lax.axis_index(stage_axis)
        n_ticks = M + S - 1
        buf = jnp.zeros_like(xs[0])

        def tick(carry, t):
            buf, ys = carry
            # stage 0 ingests microbatch t (if any); others take the rotated belt
            feed = jnp.where(t < M, t, 0)
            inject = xs[feed]
            h_in = jnp.where(stage_id == 0, inject, buf)
            h_out = run_stage(h_in)
            # rotate belt to the next stage
            nxt = jax.lax.ppermute(
                h_out, stage_axis,
                [(i, (i + 1) % S) for i in range(S)])
            # ONLY the last stage emits microbatch t-(S-1); other stages'
            # ys buffers stay zero and vanish in the cross-stage psum below.
            emit_idx = t - (S - 1)
            emit = jnp.logical_and(stage_id == S - 1, emit_idx >= 0)
            ys = jax.lax.cond(
                emit,
                lambda ys: ys.at[jnp.maximum(emit_idx, 0)].set(h_out),
                lambda ys: ys, ys)
            return (nxt, ys), None

        ys0 = jnp.zeros_like(xs)
        (_, ys), _ = jax.lax.scan(tick, (buf, ys0), jnp.arange(n_ticks))
        # replicate the last stage's emissions to every device
        return jax.lax.psum(ys, stage_axis)

    spec_p = jax.tree_util.tree_map(
        lambda l: P(stage_axis, *([None] * (l.ndim - 1))), params_stacked)
    out = shard_map(
        stage_fn, mesh=mesh,
        in_specs=(spec_p, P()),            # belt replicated; params staged
        out_specs=P(),
        check_rep=False,
    )(params_stacked, x_microbatches)
    return out


def pipeline_bubble_fraction(n_micro: int, n_stages: int) -> float:
    """GPipe bubble overhead: (S-1)/(M+S-1)."""
    return (n_stages - 1) / (n_micro + n_stages - 1)
