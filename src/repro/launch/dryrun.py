import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
"""Multi-pod dry-run: for every (architecture x input shape x mesh) cell,
``jit(step).lower(input_specs).compile()`` must succeed on the production
mesh; the compiled artifact yields the roofline terms (EXPERIMENTS.md).

The two lines above run before any other import — jax locks the device count
at first backend init, and the dry-run needs 512 placeholder CPU devices.
Nothing here allocates device memory: all inputs are ShapeDtypeStructs.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --out results/
  ... --multi-pod            # (2,16,16) pod mesh instead of (16,16)
  ... --quant psi8|psi5|none # serving weight format (default psi8)
"""
import argparse
import dataclasses
import json
import re
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, get_config, shape_applicable, ASSIGNED_ARCHS
from repro.core import quantizer as qz
from repro.launch.mesh import make_production_mesh
from repro.models import build_model
from repro.optim import adamw, cosine_schedule
from repro.runtime import sharding as shr

# TPU v5e hardware constants live in repro.perf.roofline_model (importable
# without touching this module's device-count env flag).
from repro.perf.roofline_model import PEAK_FLOPS, HBM_BW, ICI_BW  # noqa: E402


# --------------------------------------------------------------------------
# Abstract inputs (ShapeDtypeStruct stand-ins; no allocation).
# --------------------------------------------------------------------------
def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def abstract_tree(tree):
    return jax.tree_util.tree_map(
        lambda x: _sds(x.shape, x.dtype) if hasattr(x, "shape") else x, tree)


def abstract_params(model, quant: str):
    """Parameter ShapeDtypeStructs via eval_shape — no real init at scale."""
    cfg = model.cfg
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    dt = jnp.dtype(cfg.dtype)
    params = jax.tree_util.tree_map(
        lambda s: _sds(s.shape, dt if jnp.issubdtype(s.dtype, jnp.floating)
                       else s.dtype), params)
    if quant != "none":
        _, bits = qz.parse_quant_mode(quant)
        params = jax.eval_shape(
            lambda p: qz.quantize_param_tree(p, bits, pack=True), params)
    return params


def input_specs(arch: str, shape_name: str, quant: str = "psi8",
                kv_quant: str = ""):
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    cfg = get_config(arch, **({"kv_quant": kv_quant} if kv_quant else {}))
    shape = SHAPES[shape_name]
    B, S = shape.global_batch, shape.seq_len
    if shape.kind in ("train", "prefill"):
        batch = {"tokens": _sds((B, S), jnp.int32)}
        if cfg.family == "vlm":
            batch["vision_embeds"] = _sds((B, cfg.vision_patches, cfg.d_model),
                                          jnp.dtype(cfg.dtype))
            batch["positions"] = _sds((B, 3, S), jnp.int32)
        if cfg.family == "encdec":
            batch["frames"] = _sds((B, cfg.enc_frames, cfg.d_model),
                                   jnp.dtype(cfg.dtype))
        return batch
    # decode: one new token against a seq_len-deep cache
    batch = {"token": _sds((B, 1), jnp.int32)}
    if cfg.rope == "mrope":
        batch["positions"] = _sds((B, 3, 1), jnp.int32)
    else:
        batch["pos"] = _sds((B, 1), jnp.int32)
    model = build_model(cfg)
    # The roofline decode cells model the steady dense state (every slot at
    # the full context depth), where paging saves nothing — pin the dense
    # layout so the analytic byte accounting matches the cache that lowers.
    cache = jax.eval_shape(
        lambda: model.init_cache(B, S, jnp.dtype(cfg.dtype), layout="dense"))
    return {"batch": batch, "cache": abstract_tree(cache)}


# --------------------------------------------------------------------------
# Step functions.
# --------------------------------------------------------------------------
def build_step(arch: str, shape_name: str, quant: str, mesh,
               kv_quant: str = ""):
    """Returns (fn, example_args(abstract), in_shardings, out_shardings,
    static cfg info)."""
    shape = SHAPES[shape_name]
    serve_quant = quant if shape.kind != "train" else "none"
    overrides = {"quant_mode": serve_quant if shape.kind != "train" else "none"}
    if kv_quant and shape.kind == "decode":
        overrides["kv_quant"] = kv_quant
    base_cfg = get_config(arch)
    if shr.tp_enabled(base_cfg):
        overrides["act_batch_axes"] = tuple(
            a for a in shr.DP_AXES if a in mesh.axis_names)
        if shape.kind != "decode":
            # Megatron-style sequence sharding of the residual stream
            overrides["act_seq_axis"] = "model"
        if base_cfg.n_experts and base_cfg.n_experts % mesh.shape["model"] == 0:
            overrides["moe_expert_axis"] = "model"
    cfg = get_config(arch, **overrides)
    model = build_model(cfg)
    params = abstract_params(model, serve_quant if shape.kind != "train" else "none")
    pspecs = shr.param_specs(params, cfg, mesh,
                             mode="train" if shape.kind == "train" else "serve")
    psh = shr.to_shardings(pspecs, mesh)

    if shape.kind == "train":
        opt = adamw(lr=cosine_schedule(3e-4, 2000, 100_000))
        opt_state = jax.eval_shape(opt.init, params)
        osh = type(opt_state)(
            step=NamedSharding(mesh, P()),
            m=shr.to_shardings(pspecs, mesh),
            v=shr.to_shardings(pspecs, mesh))
        batch = input_specs(arch, shape_name)
        bsh = shr.to_shardings(shr.batch_specs(cfg, mesh, batch), mesh)

        def train_step(params, opt_state, batch):
            def loss_fn(p):
                loss, met = model.loss(p, batch)
                return loss, met
            (loss, met), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
            new_p, new_s, om = opt.update(grads, opt_state, params)
            return new_p, new_s, {"loss": loss, **met, **om}

        return (train_step, (params, opt_state, batch),
                (psh, osh, bsh), (psh, osh, None))

    def _logits_sharding(B):
        bax = None
        for cand in (tuple(a for a in shr.DP_AXES if a in mesh.axis_names),
                     ("data",)):
            size = int(np.prod([mesh.shape[a] for a in cand]))
            if B % size == 0:
                bax = cand
                break
        vax = ("model" if shr.tp_enabled(cfg)
               and cfg.vocab_size % mesh.shape["model"] == 0 else None)
        return NamedSharding(mesh, P(bax, vax))

    if shape.kind == "prefill":
        batch = input_specs(arch, shape_name, quant)
        bsh = shr.to_shardings(shr.batch_specs(cfg, mesh, batch), mesh)
        cache_shape = jax.eval_shape(
            lambda p, b: model.prefill(p, b)[1], params, batch)
        # typed KVCache: cache_specs reads the layout off the object and
        # returns a structure-equal KVCache of specs (DESIGN.md §5)
        csh = shr.to_shardings(shr.cache_specs(cfg, mesh, cache_shape), mesh)
        logits_sh = _logits_sharding(shape.global_batch)
        out_sh = (logits_sh, csh)

        def prefill_step(params, batch):
            return model.prefill(params, batch)

        return prefill_step, (params, batch), (psh, bsh), out_sh

    # decode
    spec = input_specs(arch, shape_name, quant, kv_quant=kv_quant)
    batch, cache = spec["batch"], spec["cache"]
    bsh = shr.to_shardings(shr.batch_specs(cfg, mesh, batch), mesh)
    csh = shr.to_shardings(shr.cache_specs(cfg, mesh, cache), mesh)
    logits_sh = _logits_sharding(shape.global_batch)

    def decode_step(params, batch, cache):
        return model.decode_step(params, batch, cache)

    return decode_step, (params, batch, cache), (psh, bsh, csh), (logits_sh, csh)


# --------------------------------------------------------------------------
# HLO collective-byte accounting.
# --------------------------------------------------------------------------
_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\][^ ]*))\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)",
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes_per_device(hlo_text: str):
    """Sum result-shape bytes of every collective in the SPMD-partitioned
    module (shapes there are per-device)."""
    per_op = {}
    for m in _COLL_RE.finditer(hlo_text):
        shape_txt, opname = m.group(1), m.group(2)
        per_op[opname] = per_op.get(opname, 0) + _shape_bytes(shape_txt)
    return sum(per_op.values()), per_op


# --------------------------------------------------------------------------
# Roofline.
# --------------------------------------------------------------------------
def model_flops(cfg, shape) -> float:
    """Standard useful-FLOPs yardstick: 6*N*D train, 2*N*D inference
    (N = active non-embedding params, D = tokens processed)."""
    n = cfg.active_param_count() - cfg.vocab_size * cfg.d_model
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch  # decode: one token per sequence


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             quant: str = "psi8", kv_quant: str = "", verbose: bool = True):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "skipped": why}
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(list(mesh.shape.values())))
    t0 = time.time()
    with mesh:
        # build_step traces eval_shape through models that carry
        # with_sharding_constraint — needs the mesh in context
        fn, args, in_sh, out_sh = build_step(arch, shape_name, quant, mesh,
                                             kv_quant=kv_quant)
        jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, list):       # jax < 0.5: per-device list of dicts
        cost = cost[0] if cost else {}
    hlo = compiled.as_text()
    coll_dev, coll_ops = collective_bytes_per_device(hlo)

    flops = float(cost.get("flops", 0.0)) if cost else 0.0
    bytes_acc = float(cost.get("bytes accessed", 0.0)) if cost else 0.0

    # Roofline terms come from the analytic model (exact per-layer counts x
    # trip counts); cost_analysis counts lax.while bodies ONCE and is kept
    # only as a diagnostic (see repro/perf/roofline_model.py + tests).
    from repro.perf.roofline_model import analytic_cell, roofline_terms
    an_quant = quant if shape.kind != "train" else "none"
    cell = analytic_cell(arch, shape_name, quant=an_quant, chips=chips,
                         mesh_model=mesh.shape.get("model", 1),
                         kv_quant=kv_quant)
    rt = roofline_terms(cell, chips=chips)
    mf = model_flops(cfg, shape)
    mem_dev = (getattr(mem, "temp_size_in_bytes", 0)
               + getattr(mem, "argument_size_in_bytes", 0))
    result = {
        "arch": arch, "shape": shape_name, "quant": quant,
        "kv_quant": kv_quant,
        "mesh": "pod2x16x16" if multi_pod else "16x16",
        "chips": chips,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        # analytic roofline (authoritative)
        **rt,
        "flops_per_dev": cell.flops / chips,
        "hbm_bytes_per_dev": cell.hbm_bytes / chips,
        "coll_bytes_per_dev_analytic": cell.coll_bytes_per_dev,
        "model_flops": mf,
        "useful_flops_ratio": mf / max(cell.flops, 1.0),
        # compiled-artifact diagnostics (scan bodies counted once)
        "hlo_flops_per_dev_once": flops,
        "hlo_bytes_per_dev_once": bytes_acc,
        "hlo_collective_bytes_per_dev_once": coll_dev,
        "collective_breakdown": coll_ops,
        "memory_per_device_bytes": mem_dev,
        "fits_hbm_16g": bool(mem_dev < 16e9),
        "memory_analysis": {
            k: getattr(mem, k) for k in
            ("temp_size_in_bytes", "argument_size_in_bytes",
             "output_size_in_bytes", "generated_code_size_in_bytes")
            if hasattr(mem, k)},
    }
    if verbose:
        print(json.dumps(result, indent=1, default=float))
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--quant", default="psi8",
                    choices=list(qz.serving_mode_choices()))
    ap.add_argument("--kv-quant", default="", choices=["", "int8"])
    ap.add_argument("--out", default=None, help="JSON output path")
    args = ap.parse_args()

    cells = []
    if args.all:
        for a in ASSIGNED_ARCHS:
            for s in SHAPES:
                cells.append((a, s))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape (or --all) required")
        cells = [(args.arch, args.shape)]

    results = []
    for arch, shape in cells:
        try:
            results.append(run_cell(arch, shape, multi_pod=args.multi_pod,
                                    quant=args.quant,
                                    kv_quant=args.kv_quant))
        except Exception as e:  # a failing cell is a bug: surface loudly
            results.append({"arch": arch, "shape": shape,
                            "error": f"{type(e).__name__}: {e}"})
            print(f"FAIL {arch} x {shape}: {e}", file=sys.stderr)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1, default=float)
    fails = [r for r in results if "error" in r]
    print(f"\n{len(results) - len(fails)}/{len(results)} cells passed")
    sys.exit(1 if fails else 0)


if __name__ == "__main__":
    main()
