"""SLO classes, scheduling policy, and bursty traffic shapes (DESIGN.md §3
"SLO scheduling").

The paper's figure of merit is MACs/W; the datacenter product requirement
wrapped around it is TAIL LATENCY under load (Jouppi et al., PAPERS.md):
inference serving is a p99-TTFT/ITL-bounded workload.  This module is the
host-side policy half of that requirement:

* **``SLOClass``** — a named priority tier with per-class TTFT/ITL
  deadlines (interactive / standard / batch by default).
* **``SLOPolicy``** — orders admission by an *aged* priority key and picks
  preemption victims.  The sort key ``priority + arrival_s / aging_s`` is
  TIME-INVARIANT (the relative order of two requests never changes as the
  clock advances), which is what lets ``Scheduler.waiting`` stay an
  insertion-sorted list; aging still guarantees no starvation, because a
  batch request that has waited ``aging_s * (its priority gap)`` seconds
  outranks every newly-arrived interactive request.
* **``parse_slo_spec``** — CLI surface for ``--slo``.
* **``bursty_heavy_tail_trace``** — the serve_bench traffic shape this
  subsystem exists for: bursty arrivals, heavy-tail prompt lengths and
  decode budgets, mixed classes.
* **``slo_report``** — per-class deadline-attainment summary.

Import discipline: this module may import ``repro.launch.scheduler`` (for
``Request``); the scheduler must NOT import this module — it takes any
policy object with a ``sort_key`` duck-type, staying SLO-agnostic.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.launch.scheduler import Request


# ---------------------------------------------------------------------------
# Classes.
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class SLOClass:
    """One service tier: ``priority`` orders admission (lower = more
    urgent); the deadlines are *reporting* targets (``slo_report``), not
    hard gates — the scheduler optimizes for them, it does not reject."""
    name: str
    priority: int
    ttft_deadline_s: float      # arrival -> first token target
    itl_deadline_s: float       # per-token gap target

    def __post_init__(self):
        if not self.name:
            raise ValueError("SLOClass needs a non-empty name")
        if not (self.ttft_deadline_s > 0 and self.itl_deadline_s > 0):
            raise ValueError(
                f"class {self.name!r}: deadlines must be > 0, got "
                f"ttft={self.ttft_deadline_s} itl={self.itl_deadline_s}")


# Finite deadlines even for batch (json.dump(..., allow_nan=False) of
# BENCH_serve.json would reject Infinity) — batch just gets generous ones.
DEFAULT_CLASSES: Tuple[SLOClass, ...] = (
    SLOClass("interactive", 0, ttft_deadline_s=0.5, itl_deadline_s=0.10),
    SLOClass("standard", 1, ttft_deadline_s=2.0, itl_deadline_s=0.25),
    SLOClass("batch", 2, ttft_deadline_s=30.0, itl_deadline_s=5.0),
)


# ---------------------------------------------------------------------------
# Policy.
# ---------------------------------------------------------------------------
class SLOPolicy:
    """Aged-priority admission ordering + preemption victim selection.

    ``aging_s`` is the seconds of waiting that count as one priority level:
    ``sort_key`` = ``(priority + arrival_s / aging_s, arrival_s, rid)``.
    Smaller sorts first; within a class this is FIFO, across classes an
    older low-priority request eventually outranks younger urgent ones —
    no class starves.  ``reserve_frac`` is the optimistic-admission knob
    (DESIGN.md §3): admission reserves blocks for the bucketed prompt plus
    only this fraction of the remaining decode budget, instead of the
    worst case; the shortfall is paid on demand under the preemption
    pressure path.
    """

    def __init__(self, classes: Sequence[SLOClass] = DEFAULT_CLASSES, *,
                 aging_s: float = 30.0, reserve_frac: float = 0.25):
        if not classes:
            raise ValueError("SLOPolicy needs at least one class")
        names = [c.name for c in classes]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate class names in {names}")
        if not aging_s > 0:
            raise ValueError(f"aging_s must be > 0, got {aging_s}")
        if not 0.0 <= reserve_frac <= 1.0:
            raise ValueError(
                f"reserve_frac must be in [0, 1], got {reserve_frac}")
        self.classes: Tuple[SLOClass, ...] = tuple(classes)
        self.aging_s = float(aging_s)
        self.reserve_frac = float(reserve_frac)
        self._by_name: Dict[str, SLOClass] = {c.name: c for c in self.classes}

    # ---- class resolution ----
    def class_of(self, req: Request) -> Optional[SLOClass]:
        """The request's class by name, else the first class matching its
        priority, else None (unclassed requests still schedule by their
        bare ``priority``; they just don't appear in ``slo_report``)."""
        cls = self._by_name.get(req.slo_class)
        if cls is not None:
            return cls
        return next((c for c in self.classes if c.priority == req.priority),
                    None)

    def mix(self, weights: Sequence[float]) -> List[Tuple[str, int, float]]:
        """``poisson_trace(priority_mix=...)`` entries for these classes."""
        if len(weights) != len(self.classes):
            raise ValueError(f"need {len(self.classes)} weights, "
                             f"got {len(weights)}")
        return [(c.name, c.priority, float(w))
                for c, w in zip(self.classes, weights)]

    # ---- scheduler hooks ----
    def sort_key(self, req: Request) -> Tuple[float, float, int]:
        """Admission order (smaller first). Time-invariant — see class doc."""
        return (req.priority + req.arrival_s / self.aging_s,
                req.arrival_s, req.rid)

    def victim_key(self, req: Request) -> Tuple[int, float, int]:
        """Preemption victim order (LARGER = preferred victim): lowest
        priority tier first, youngest within a tier (it has the least
        pool-resident work to throw away and re-prefill)."""
        return (req.priority, req.arrival_s, req.rid)


def parse_slo_spec(spec: str) -> Optional[SLOPolicy]:
    """Parse the ``--slo`` flag.

    Grammar (README "Serving flags"):

      off                      -> None (FIFO + worst-case reservation)
      default                  -> SLOPolicy(DEFAULT_CLASSES)
      name:prio:ttft:itl,...   -> custom classes
      ...@aging=S@reserve=F    -> policy knobs, appendable to either form
    """
    spec = (spec or "").strip()
    if spec in ("", "off", "none"):
        return None
    head, *knob_parts = spec.split("@")
    knobs: Dict[str, float] = {}
    for part in knob_parts:
        k, eq, v = part.partition("=")
        if not eq or k not in ("aging", "reserve"):
            raise ValueError(
                f"bad --slo knob {part!r}: expected aging=S or reserve=F")
        try:
            knobs["aging_s" if k == "aging" else "reserve_frac"] = float(v)
        except ValueError:
            raise ValueError(f"bad --slo knob value {part!r}") from None
    if head == "default":
        return SLOPolicy(DEFAULT_CLASSES, **knobs)
    classes = []
    for item in head.split(","):
        fields = item.split(":")
        if len(fields) != 4:
            raise ValueError(
                f"bad --slo class {item!r}: expected name:priority:"
                f"ttft_deadline_s:itl_deadline_s")
        try:
            classes.append(SLOClass(fields[0], int(fields[1]),
                                    ttft_deadline_s=float(fields[2]),
                                    itl_deadline_s=float(fields[3])))
        except ValueError as e:
            raise ValueError(f"bad --slo class {item!r}: {e}") from None
    return SLOPolicy(classes, **knobs)


# ---------------------------------------------------------------------------
# Bursty heavy-tail traffic (serve_bench's SLO section).
# ---------------------------------------------------------------------------
def bursty_heavy_tail_trace(
        n_requests: int, *, vocab_size: int, seed: int,
        burst_size: int = 4, burst_gap_s: float = 0.5,
        intra_gap_s: float = 0.005,
        short_prompt: int = 8, long_prompt: int = 56, long_frac: float = 0.3,
        short_new: int = 8, long_new: int = 32,
        mix: Optional[Sequence[Tuple[str, int, float]]] = None
) -> List[Request]:
    """The traffic shape SLO scheduling exists for: requests arrive in
    bursts of ``burst_size`` (back-to-back within a burst, ``burst_gap_s``
    between bursts), and a ``long_frac`` heavy tail of requests carries a
    long prompt AND a long decode budget — without chunked prefill one of
    those stalls every running decode; without preemption the worst-case
    reservation of a few of them empties the pool.  Deterministic given
    ``seed``; classes drawn from ``mix`` (same format as
    ``poisson_trace(priority_mix=...)``), long requests biased toward the
    LAST (lowest-priority) entry so the preemption victims are the cheap
    ones.
    """
    if n_requests <= 0:
        raise ValueError(f"n_requests must be > 0, got {n_requests}")
    if not 0.0 <= long_frac <= 1.0:
        raise ValueError(f"long_frac must be in [0, 1], got {long_frac}")
    rng = np.random.default_rng(seed)
    mix_p = None
    if mix:
        w = np.asarray([m[2] for m in mix], np.float64)
        if (w < 0).any() or w.sum() <= 0:
            raise ValueError(f"mix weights must be non-negative with a "
                             f"positive sum, got {list(w)}")
        mix_p = w / w.sum()
    reqs: List[Request] = []
    t = 0.0
    for i in range(n_requests):
        if i and i % burst_size == 0:
            t += burst_gap_s
        elif i:
            t += intra_gap_s
        is_long = bool(rng.random() < long_frac)
        plen = long_prompt if is_long else short_prompt
        budget = long_new if is_long else short_new
        name, prio = "", 0
        if mix_p is not None:
            if is_long:           # heavy tail skews to the last (batchiest)
                j = len(mix_p) - 1 if rng.random() < 0.7 else \
                    int(rng.choice(len(mix_p), p=mix_p))
            else:
                j = int(rng.choice(len(mix_p), p=mix_p))
            name, prio, _ = mix[j]
        prompt = rng.integers(0, vocab_size, size=(plen,)).astype(np.int32)
        reqs.append(Request(rid=i, prompt=prompt, max_new=int(budget),
                            arrival_s=t, priority=int(prio),
                            slo_class=str(name)))
    return reqs


# ---------------------------------------------------------------------------
# Reporting.
# ---------------------------------------------------------------------------
def slo_report(requests: Sequence[Request],
               policy: SLOPolicy) -> Dict[str, Dict]:
    """Per-class deadline attainment over a finished request set: fraction
    of requests whose TTFT met the class deadline, fraction of TOKEN GAPS
    that met the ITL deadline (an ITL SLO is per token, not per request),
    plus the tail percentiles behind them.  Requests no class claims are
    skipped.  All values finite (JSON-strict)."""
    by_class: Dict[str, List[Request]] = {c.name: [] for c in policy.classes}
    for r in requests:
        cls = policy.class_of(r)
        if cls is not None:
            by_class[cls.name].append(r)
    report: Dict[str, Dict] = {}
    for cls in policy.classes:
        rs = by_class[cls.name]
        ttfts = np.asarray([r.ttft_s for r in rs], np.float64)
        ttfts = ttfts[~np.isnan(ttfts)]
        gaps = (np.concatenate([r.itl_gaps for r in rs])
                if rs else np.empty((0,), np.float64))
        report[cls.name] = {
            "priority": cls.priority,
            "n_requests": len(rs),
            "ttft_deadline_s": cls.ttft_deadline_s,
            "itl_deadline_s": cls.itl_deadline_s,
            "p50_ttft_s": float(np.percentile(ttfts, 50)) if ttfts.size
            else 0.0,
            "p99_ttft_s": float(np.percentile(ttfts, 99)) if ttfts.size
            else 0.0,
            "ttft_attainment": float(np.mean(ttfts <= cls.ttft_deadline_s))
            if ttfts.size else 1.0,
            "p99_itl_s": float(np.percentile(gaps, 99)) if gaps.size
            else 0.0,
            "itl_attainment": float(np.mean(gaps <= cls.itl_deadline_s))
            if gaps.size else 1.0,
            "preemptions": int(sum(r.preemptions for r in rs)),
        }
    return report
