"""End-to-end training driver.

Runs at two scales with the same code path:
  * CPU quickstart (reduced config, 1 device) — examples/ and CI;
  * production mesh (pass --mesh 16x16 under the dry-run device flag).

Features wired in: QAT (the paper's quantized training), AdamW + cosine
schedule, gradient clipping, optional int8 error-feedback gradient
compression for the cross-pod all-reduce, checkpoint/restore with exact
data-stream resume, straggler monitoring hooks.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-8b --reduced \
      --steps 100 --quant qat8 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config, reduced_config
from repro.data.pipeline import TokenStream, make_batch_for
from repro.models import build_model
from repro.optim import adamw, cosine_schedule
from repro.optim.compress import compress_gradients, decompress_gradients
from repro.runtime.straggler import StragglerMonitor


def make_train_step(model, opt, compress: bool = False):
    def train_step(params, opt_state, err_fb, batch):
        def loss_fn(p):
            return model.loss(p, batch)

        (loss, met), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        if compress:
            comp, err_fb = compress_gradients(grads, err_fb)
            grads = decompress_gradients(comp)
        new_p, new_s, om = opt.update(grads, opt_state, params)
        return new_p, new_s, err_fb, {"loss": loss, **met, **om}

    return jax.jit(train_step, donate_argnums=(0, 1, 2))


def train(cfg, steps: int, ckpt_dir=None, seed: int = 0,
          compress: bool = False, save_every: int = 50, log_every: int = 10,
          batch_size: int = 8, seq_len: int = 128):
    model = build_model(cfg)
    opt = adamw(lr=cosine_schedule(3e-4, max(steps // 10, 1), steps))
    params = model.init(jax.random.PRNGKey(seed))
    opt_state = opt.init(params)
    err_fb = (jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
        if compress else {})
    stream = TokenStream(cfg.vocab_size, seq_len, batch_size, seed=seed)
    mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None
    start = 0
    if mgr and mgr.latest_step() is not None:
        state, extra = mgr.restore()
        params, opt_state = state["params"], _restore_opt(opt_state, state["opt"])
        stream.load_state_dict(extra["data"])
        start = extra["step"]
        print(f"resumed from step {start}")

    step_fn = make_train_step(model, opt, compress)
    monitor = StragglerMonitor(n_hosts=1)
    history = []
    for step in range(start, steps):
        toks = next(stream)
        batch = make_batch_for(cfg, batch_size, seq_len,
                               jax.random.PRNGKey(step))
        batch["tokens"] = jnp.asarray(toks)
        t0 = time.time()
        params, opt_state, err_fb, metrics = step_fn(
            params, opt_state, err_fb, batch)
        loss = float(metrics["loss"])
        dt = time.time() - t0
        monitor.observe([dt])
        history.append(loss)
        if step % log_every == 0:
            print(f"step {step:5d} loss {loss:.4f} "
                  f"({dt * 1e3:.0f} ms, lr {float(metrics['lr']):.2e})")
        if mgr and (step + 1) % save_every == 0:
            mgr.save(step + 1,
                     {"params": params, "opt": _opt_tree(opt_state)},
                     extra={"step": step + 1, "data": stream.state_dict()},
                     blocking=False)
    if mgr:
        mgr.save(steps, {"params": params, "opt": _opt_tree(opt_state)},
                 extra={"step": steps, "data": stream.state_dict()})
        mgr.wait()
    return params, history


def _opt_tree(s):
    return {"step": s.step, "m": s.m, "v": s.v}


def _restore_opt(proto, tree):
    return type(proto)(step=jnp.asarray(tree["step"]), m=tree["m"],
                       v=tree["v"])


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--quant", default="none",
                    choices=["none", "qat5", "qat8"])
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()
    cfg = get_config(args.arch, quant_mode=args.quant)
    if args.reduced:
        cfg = reduced_config(cfg, quant_mode=args.quant)
    _, history = train(cfg, args.steps, ckpt_dir=args.ckpt_dir,
                       compress=args.compress_grads,
                       batch_size=args.batch, seq_len=args.seq)
    print(f"final loss {history[-1]:.4f} (from {history[0]:.4f})")


if __name__ == "__main__":
    main()
