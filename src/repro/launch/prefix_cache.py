"""Shared-prefix block cache over the paged KV pool (DESIGN.md §3).

The dominant production traffic shape is millions of requests sharing one
system prompt; without this module every admission re-prefills identical KV
state from scratch.  The cheapest MAC is the one never recomputed — the
paper's MACs/W thesis applied at the serving layer — so the engine caches
completed prompts' KV *blocks* and serves later requests' common prefixes
straight out of the pool.

Design (host-side only; the pool tensors never move):

* **Keys are block-aligned token-prefix hash chains.**  One cache entry per
  physical block: entry ``i`` of a prompt is keyed by
  ``H(parent_key, tokens[i*bs:(i+1)*bs])`` (sha256 — a collision would
  silently serve the wrong KV, so no Python ``hash``).  Chaining makes the
  key cover the FULL prefix ``tokens[:(i+1)*bs]``, which is exactly what
  block ``i``'s KV depends on under causal attention, and dedups shared
  sub-prefixes across entries.
* **Entries pin their block in the ``BlockAllocator``** (``ref_block`` on
  publish, ``unref_block`` on eviction), so a cached block is never handed
  back to the free pool while the cache can still serve it, and a block is
  freed only when the last reference — request or cache — drops.
* **Lookup** walks the chain from the root and returns the longest cached
  block run, capped so at least one suffix token remains to prefill (the
  engine needs the last prompt position's logits).  Matched entries move
  to MRU.
* **Eviction is LRU over unreferenced entries only** (block refcount 1 —
  the cache's own pin): an entry whose block a live request still shares
  is skipped.  ``Scheduler.admit`` evicts on demand when a reservation
  would not fit; ``drain`` empties the cache (the "initial allocator
  state" of the churn tests includes draining the LRU).
"""
from __future__ import annotations

import hashlib
import math
from collections import OrderedDict
from typing import Dict, List

import numpy as np

from repro.models.kvcache import full_blocks

_ROOT = b"prefix-cache-root"


def _chain_key(parent: bytes, block_tokens: np.ndarray) -> bytes:
    h = hashlib.sha256(parent)
    h.update(np.ascontiguousarray(block_tokens, dtype=np.int32).tobytes())
    return h.digest()


class PrefixCache:
    """Block-aligned token-prefix hash chains -> physical pool blocks, with
    LRU eviction of unreferenced entries (DESIGN.md §3 "Prefix cache")."""

    def __init__(self, block_size: int, align_tokens: int = 0):
        if block_size <= 0:
            raise ValueError(f"block_size must be > 0, got {block_size}")
        self.block_size = int(block_size)
        # ``align_tokens`` (the engine's prefill bucket): cap hits so the
        # reuse offset ``pos0 = n_hit * block_size`` lands on a bucket
        # boundary.  The engine's reservation / fail-fast / table-width
        # math is all stated in terms of ``bucket(len(prompt))``, which
        # bounds the suffix coverage ``pos0 + bucket(len - pos0)`` ONLY
        # when pos0 is bucket-aligned — a misaligned hit (block_size not a
        # multiple of the bucket) would over-allocate past the admission
        # reservation mid-serve.
        self._hit_step = self.hit_alignment_step(block_size, align_tokens)
        self._entries: "OrderedDict[bytes, int]" = OrderedDict()  # key -> blk
        # ---- counters (reported into serve stats / BENCH_serve.json) ----
        self.lookups = 0
        self.hits = 0
        self.tokens_reused = 0
        self.published_blocks = 0
        self.evicted_blocks = 0
        # restore path (DESIGN.md §3 "SLO scheduling"): lookups on behalf
        # of a PREEMPTED request re-attaching its own published KV — the
        # swap-layer traffic, reported separately from organic prefix hits
        self.restores = 0
        self.restored_tokens = 0

    @staticmethod
    def hit_alignment_step(block_size: int, align_tokens: int) -> int:
        """Hit depths are usable in multiples of this many blocks
        (``lcm(block_size, align_tokens) / block_size``) — the single
        source of truth shared with the engine's warmup, which must
        compile exactly the hit depths lookups can return."""
        if not align_tokens:
            return 1
        return math.lcm(int(block_size), int(align_tokens)) // int(block_size)

    def __len__(self) -> int:
        return len(self._entries)

    @property
    def cached_blocks(self) -> List[int]:
        return list(self._entries.values())

    # ------------------------------------------------------------- lookup
    def lookup(self, prompt: np.ndarray) -> List[int]:
        """Longest cached block run covering a block-aligned prefix of
        ``prompt``, capped so at least one suffix token remains to prefill.
        Returns the physical block ids in logical order (possibly empty).
        Pure read (plus LRU touch) — the caller decides whether to
        ``BlockAllocator.attach`` them and ``note_lookup`` the outcome."""
        bs = self.block_size
        blocks: List[int] = []
        keys: List[bytes] = []
        key = _ROOT
        # strict `<`: a hit covering the whole prompt would leave nothing
        # to prefill, and the engine needs the last prompt token's logits
        while (len(blocks) + 1) * bs < len(prompt):
            key = _chain_key(key, prompt[len(blocks) * bs:
                                         (len(blocks) + 1) * bs])
            blk = self._entries.get(key)
            if blk is None:
                break
            keys.append(key)
            blocks.append(blk)
        self._touch(keys)
        # bucket alignment (see __init__): trim to the deepest hit whose
        # token offset lands on the engine's prefill-bucket grid
        return blocks[:(len(blocks) // self._hit_step) * self._hit_step]

    def _touch(self, chain_keys: List[bytes]) -> None:
        """LRU-touch a chain DEEPEST-FIRST, leaving the root most recent:
        a lookup cannot use entry i+1 without entry i, so eviction must
        take leaves before their ancestors — evicting a root first would
        orphan its still-pinned descendants (unreachable dead weight)."""
        for k in reversed(chain_keys):
            self._entries.move_to_end(k)

    def note_lookup(self, hit_blocks: List[int],
                    restore: bool = False) -> None:
        """Record one admission's lookup outcome (kept separate from
        ``lookup`` so head-of-line retries don't inflate the hit rate).
        ``restore=True`` marks a preempted request's re-admission — its
        hit tokens are ALSO counted as swap-restore traffic."""
        self.lookups += 1
        if hit_blocks:
            self.hits += 1
            self.tokens_reused += len(hit_blocks) * self.block_size
            if restore:
                self.restores += 1
                self.restored_tokens += len(hit_blocks) * self.block_size

    # ------------------------------------------------------------ publish
    def publish(self, prompt: np.ndarray, held_blocks: List[int],
                allocator) -> int:
        """Insert a retiring request's completed prompt into the cache: its
        fully-filled prompt blocks (``len(prompt) // block_size`` of them —
        block ``i``'s KV depends only on ``tokens[:(i+1)*bs]``, so partial
        tail blocks are never shareable) are pinned via ``ref_block``.
        ``held_blocks`` is the request's logical-order block list
        (``BlockAllocator.owned_by``).  Chain keys already present keep
        their existing block (first publisher wins).  Returns how many new
        entries were added."""
        bs = self.block_size
        n_full = min(full_blocks(len(prompt), bs), len(held_blocks))
        key, added, keys = _ROOT, 0, []
        for i in range(n_full):
            key = _chain_key(key, prompt[i * bs:(i + 1) * bs])
            keys.append(key)
            if key in self._entries:
                continue
            blk = held_blocks[i]
            allocator.ref_block(blk)
            self._entries[key] = blk
            added += 1
        self._touch(keys)          # leaves-before-ancestors LRU order
        self.published_blocks += added
        return added

    # ------------------------------------------------------------- evict
    def _evict_entry(self, key: bytes, allocator) -> None:
        blk = self._entries.pop(key)
        allocator.unref_block(blk)
        self.evicted_blocks += 1

    def evict_until(self, allocator, need: int) -> int:
        """LRU-evict unreferenced entries (block refcount == 1, the cache's
        own pin) until ``allocator.can_reserve(need)`` or nothing more is
        evictable; returns how many entries were evicted."""
        n = 0
        while not allocator.can_reserve(need):
            victim = next((k for k, blk in self._entries.items()
                           if allocator.refcount[blk] == 1), None)
            if victim is None:
                break                       # everything left is in use
            self._evict_entry(victim, allocator)
            n += 1
        return n

    def drain(self, allocator) -> int:
        """Evict every evictable entry (end-of-serve teardown: with
        refcounts, "allocator back to initial" includes draining the LRU).
        Returns how many entries were evicted."""
        n = 0
        for key in [k for k, blk in self._entries.items()
                    if allocator.refcount[blk] == 1]:
            self._evict_entry(key, allocator)
            n += 1
        return n

    # -------------------------------------------------------------- stats
    def stats(self) -> Dict:
        return {
            "lookups": self.lookups,
            "hits": self.hits,
            "hit_rate": (self.hits / self.lookups if self.lookups else 0.0),
            "tokens_reused": self.tokens_reused,
            "restores": self.restores,
            "restored_tokens": self.restored_tokens,
            "published_blocks": self.published_blocks,
            "evicted_blocks": self.evicted_blocks,
            "entries": len(self._entries),
        }
