"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state): (16, 16) = 256 chips per pod, data x model; multi-pod adds
the leading "pod" axis (2 pods = 512 chips).
"""
from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh over the first prod(shape) devices (tests, elastic)."""
    n = int(np.prod(shape))
    devs = np.asarray(jax.devices()[:n]).reshape(shape)
    return jax.sharding.Mesh(devs, axes)
