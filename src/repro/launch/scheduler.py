"""Iteration-level scheduler for the continuous-batching serving engine.

Orca-style continuous batching (DESIGN.md §3): the decode step is a fixed
``(max_batch, 1)`` tensor over ``max_batch`` *slots*; the scheduler owns which
request occupies which slot.  New requests are admitted into free slots
mid-decode, sequences retire at EOS / their own ``max_new`` (freeing the slot
immediately), and a waiting queue orders admission — FIFO by default, or by
an SLO policy's aged priority key (``repro.launch.slo``, DESIGN.md §3 "SLO
scheduling").  Under an SLO policy the scheduler also supports *preemption*:
``preempt`` evicts a running request from its slot, publishes its pool
blocks into the prefix cache (so resume is a cheap suffix re-prefill), and
re-queues it; accounting (``queue_s``/``ttft_s``) survives re-admission.
The engine (``repro.launch.serve``) is the device half; this module is pure
host-side bookkeeping — request queue, Poisson arrival simulation, slot
allocation, and per-request latency accounting — so it is unit-testable
without a model.
"""
from __future__ import annotations

import bisect
import dataclasses
import heapq
from collections import deque
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np


# ---------------------------------------------------------------------------
# Requests and arrival traces.
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class Request:
    """One serving request plus its lifecycle accounting (filled in by the
    scheduler/engine as the request moves arrival -> admit -> retire)."""
    rid: int
    prompt: np.ndarray                  # (S,) int32 token ids
    max_new: int                        # per-request generation budget
    arrival_s: float = 0.0              # trace time the request shows up
    # --- SLO class (DESIGN.md §3 "SLO scheduling"): lower = more urgent;
    # the FIFO scheduler ignores it, an SLOPolicy orders admission by it ---
    priority: int = 0
    slo_class: str = ""                 # class name, for per-class reporting

    # --- engine-filled accounting ---
    admit_s: Optional[float] = None     # FIRST admission into a decode slot
    first_token_s: Optional[float] = None
    finish_s: Optional[float] = None
    slot: Optional[int] = None          # slot the request decoded in
    tokens: List[int] = dataclasses.field(default_factory=list)
    token_s: List[float] = dataclasses.field(default_factory=list)
    # --- preemption accounting (DESIGN.md §3 "SLO scheduling") ---
    preemptions: int = 0                # times evicted from a slot mid-serve
    prefilled_tokens: int = 0           # tokens the engine actually forwarded
    # --- prefix-cache accounting (DESIGN.md §3 "Prefix cache") ---
    prefix_blocks: List[int] = dataclasses.field(default_factory=list)
    prefix_hit_tokens: int = 0          # tokens ever served from the cache
    #                                     (cumulative across re-admissions)
    # --- speculative-decode accounting (DESIGN.md "Self-speculative") ---
    spec_rounds: int = 0                # draft+verify rounds this request ran
    spec_accepted: int = 0              # draft tokens accepted across rounds
    draft_s: float = 0.0                # wall seconds spent in draft passes

    @property
    def latency_s(self) -> float:
        """Arrival -> completion (includes queueing — the p99 that matters).
        NaN while the request is unfinished (a half-served request has no
        latency; ``summarize`` skips NaNs)."""
        if self.finish_s is None:
            return float("nan")
        return self.finish_s - self.arrival_s

    @property
    def ttft_s(self) -> float:
        """Arrival -> first generated token; NaN before the first token."""
        if self.first_token_s is None:
            return float("nan")
        return self.first_token_s - self.arrival_s

    @property
    def queue_s(self) -> float:
        """Arrival -> admission; NaN while still queued."""
        if self.admit_s is None:
            return float("nan")
        return self.admit_s - self.arrival_s

    @property
    def accepted_per_step(self) -> float:
        """Mean draft tokens accepted per speculative round (0..k).  NaN for
        requests that never ran a speculative round (spec off, or retired at
        prefill) — ``summarize`` skips NaNs, mirroring the latency fields."""
        if self.spec_rounds == 0:
            return float("nan")
        return self.spec_accepted / self.spec_rounds

    @property
    def out(self) -> np.ndarray:
        return np.asarray(self.tokens, np.int32)

    @property
    def full_seq(self) -> np.ndarray:
        """Prompt followed by everything emitted so far — the token sequence
        a preempted request must restore before decoding can continue (the
        re-admission prefix-cache lookup runs over THIS, not the prompt)."""
        if not self.tokens:
            return self.prompt
        return np.concatenate([self.prompt,
                               np.asarray(self.tokens, np.int32)])

    def emit(self, token: int, now: float) -> None:
        """Record one generated token at wall time ``now``: sets
        ``first_token_s`` exactly once (a restore after preemption must NOT
        reset TTFT) and timestamps the token for inter-token latency."""
        if self.first_token_s is None:
            self.first_token_s = now
        self.tokens.append(int(token))
        self.token_s.append(float(now))

    @property
    def itl_gaps(self) -> np.ndarray:
        """Inter-token gaps (seconds) between consecutive emissions; empty
        for 0- and 1-token requests (no gap exists — they must contribute
        nothing to the percentiles, not zeros)."""
        if len(self.token_s) < 2:
            return np.empty((0,), np.float64)
        return np.diff(np.asarray(self.token_s, np.float64))


def poisson_trace(n_requests: int, *, rate_rps: float, prompt_len: int,
                  max_new: int, vocab_size: int, seed: int = 0,
                  min_new: Optional[int] = None,
                  prompt_jitter: int = 0,
                  shared_prefix_len: int = 0,
                  priority_mix: Optional[Sequence[Tuple[str, int, float]]]
                  = None) -> List[Request]:
    """Simulated open-loop arrival process: exponential inter-arrival times at
    ``rate_rps`` requests/s, heterogeneous decode budgets in
    ``[min_new, max_new]`` (default min_new: ``max(1, max_new // 4)``; the
    heterogeneity is what a batch-synchronous server pays for — every
    sequence in a static batch runs to the batch max).  Deterministic given
    ``seed``.

    ``shared_prefix_len`` > 0 prepends ONE fixed random prefix of that many
    tokens to every prompt — the shared-system-prompt traffic shape the
    prefix cache (DESIGN.md §3) exists for; ``prompt_len`` then sizes only
    the per-request unique tail.

    ``priority_mix`` draws each request's SLO class i.i.d. from a weighted
    mix of ``(class_name, priority, weight)`` entries (weights need not sum
    to 1; they are normalized).  ``None`` leaves every request at priority 0
    with no class — the FIFO-equivalent trace.
    """
    # rate_rps == 0 used to raise a bare ZeroDivisionError below, and a
    # negative rate silently produced a time-REVERSED trace (negative
    # exponential inter-arrivals); both are caller bugs — reject loudly.
    if not rate_rps > 0:
        raise ValueError(
            f"rate_rps must be > 0 (requests/s), got {rate_rps!r}")
    if shared_prefix_len < 0:
        raise ValueError(
            f"shared_prefix_len must be >= 0, got {shared_prefix_len}")
    rng = np.random.default_rng(seed)
    min_new = max(1, max_new // 4) if min_new is None else max(1, min_new)
    if min_new > max_new:
        raise ValueError(f"min_new={min_new} exceeds max_new={max_new}")
    shared = (rng.integers(0, vocab_size, size=(shared_prefix_len,))
              .astype(np.int32) if shared_prefix_len else None)
    mix_p = None
    if priority_mix is not None:
        if not priority_mix:
            raise ValueError("priority_mix must be a non-empty sequence of "
                             "(class_name, priority, weight)")
        w = np.asarray([float(m[2]) for m in priority_mix], np.float64)
        if (w < 0).any() or w.sum() <= 0:
            raise ValueError(f"priority_mix weights must be non-negative "
                             f"with a positive sum, got {list(w)}")
        mix_p = w / w.sum()
    reqs, t = [], 0.0
    for i in range(n_requests):
        t += float(rng.exponential(1.0 / rate_rps))
        plen = prompt_len
        if prompt_jitter:
            plen = max(1, prompt_len + int(rng.integers(-prompt_jitter,
                                                        prompt_jitter + 1)))
        prompt = rng.integers(0, vocab_size, size=(plen,)).astype(np.int32)
        if shared is not None:
            prompt = np.concatenate([shared, prompt])
        name, prio = "", 0
        if mix_p is not None:
            name, prio, _ = priority_mix[int(rng.choice(len(mix_p),
                                                        p=mix_p))]
        reqs.append(Request(rid=i, prompt=prompt,
                            max_new=int(rng.integers(min_new, max_new + 1)),
                            arrival_s=t, priority=int(prio),
                            slo_class=str(name)))
    return reqs


def replay_round(toks: np.ndarray, active: np.ndarray,
                 remaining: np.ndarray, eos_id: int):
    """Host replay of the multi-step decode round's IN-KERNEL retirement
    recurrence (DESIGN.md §3 "Multi-step decode & host overlap").

    ``toks`` is the raw (M, B) per-step greedy token block a
    ``decode_multi`` round returned; ``active``/``remaining`` are the
    round-ENTRY mirrors.  Step by step, exactly as the device scan did::

        for each step, for each entry-active slot:
            emit toks[step, slot]; remaining -= 1
            active &= (token != eos_id) and (remaining > 0)

    Because the recurrence is identical (and the device froze retired
    slots' state via the masked-decode contract), the emitted streams are
    bit-identical to a step-at-a-time horizon-1 loop, and the returned
    exit state equals the device carry row-for-row — the serve loop uses
    it to keep its host mirrors in lockstep with the device-resident
    carry.  Pure host math: unit-testable without a model.

    Returns (emitted, active_out, remaining_out) — ``emitted[slot]`` is the
    list of tokens slot emitted this round (EOS included, as in the
    single-step loop), the arrays are fresh copies.
    """
    M, B = toks.shape
    act = np.asarray(active).copy()
    rem = np.asarray(remaining).copy()
    emitted = [[] for _ in range(B)]
    for m in range(M):
        for b in np.flatnonzero(act):
            t = int(toks[m, b])
            emitted[b].append(t)
            rem[b] -= 1
            if t == eos_id or rem[b] <= 0:
                act[b] = False
    return emitted, act, rem


# ---------------------------------------------------------------------------
# Slot allocation.
# ---------------------------------------------------------------------------
class SlotAllocator:
    """Fixed pool of ``n_slots`` decode slots, optionally partitioned into
    per-shard pools.

    On a sharded mesh the Executor lays the slot dim of the decode cache out
    contiguously over the data axes (``sharding.slot_shard_map``); admission
    then balances data-parallel work by taking a free slot from the shard
    with the MOST free slots (ties -> lowest shard index), lowest slot index
    within the shard.  With ``n_shards == 1`` (the single-device no-op path)
    this degenerates to exactly the classic lowest-index-first reuse.
    """

    def __init__(self, n_slots: int, n_shards: int = 1,
                 shard_of: Optional[Sequence[int]] = None):
        self.n_slots = n_slots
        self.n_shards = max(int(n_shards), 1)
        if shard_of is None:  # contiguous chunks, GSPMD's layout
            shard_of = [(s * self.n_shards) // n_slots for s in range(n_slots)]
        self.shard_of = [int(s) for s in shard_of]
        assert len(self.shard_of) == n_slots
        # Per-shard min-heaps (lowest index pops first — the classic reuse
        # order the property tests assert).  Heaps make release O(log n)
        # instead of the old re-sort's O(n log n) per freed slot, which went
        # quadratic over a retirement burst.
        self._free: List[List[int]] = [
            [s for s in range(n_slots) if self.shard_of[s] == i]
            for i in range(self.n_shards)]
        for pool in self._free:
            heapq.heapify(pool)
        self.occupant: List[Optional[int]] = [None] * n_slots  # slot -> rid

    @property
    def free_count(self) -> int:
        return sum(len(f) for f in self._free)

    def free_per_shard(self) -> List[int]:
        return [len(f) for f in self._free]

    def alloc(self, rid: int) -> int:
        shard = max(range(self.n_shards),
                    key=lambda i: (len(self._free[i]), -i))
        slot = heapq.heappop(self._free[shard])
        self.occupant[slot] = rid
        return slot

    def release(self, slot: int) -> None:
        if self.occupant[slot] is None:
            raise ValueError(f"slot {slot} is already free")
        self.occupant[slot] = None
        heapq.heappush(self._free[self.shard_of[slot]], slot)


# ---------------------------------------------------------------------------
# Block allocation (paged KV cache, DESIGN.md §3).
# ---------------------------------------------------------------------------
class BlockAllocator:
    """Host-side allocator for the paged KV cache's fixed pool of
    ``n_blocks`` physical blocks, optionally partitioned into per-shard
    pools mirroring the pool tensor's block-over-data layout
    (``sharding.block_shard_map``).

    Lifecycle per request (driven by the Scheduler/engine):

      * ``reserve(rid, n)`` at admission — books the request's WORST-CASE
        block count (bucketed prompt + its own ``max_new``, minus any
        prefix-cache hit) so a running request can never starve mid-decode;
        admission is gated on ``can_reserve`` (free minus everyone's
        outstanding reservations).
      * ``alloc(rid)`` on demand — prefill insertion takes the prompt's
        blocks, decode takes one more each time a sequence crosses a
        block boundary; every alloc draws down the reservation.
      * ``release(rid)`` at retirement — drops every reference ``rid``
        holds AND the unused tail of the reservation (early EOS gives
        capacity back).

    **Reference counting** (DESIGN.md §3 "Prefix cache"): every in-use
    block carries a refcount.  ``alloc`` creates an exclusive block
    (refcount 1); ``attach`` shares already-populated blocks read-only into
    another request (refcount += 1); the prefix cache pins published blocks
    with ``ref_block``/``unref_block``.  A block returns to the free pool
    only when its LAST reference drops, and ``fork`` gives copy-on-write
    semantics: a request that must mutate a shared block trades its shared
    reference for a fresh exclusive block (the caller copies the contents).

    Invariants (property-tested): a block is never handed out twice while
    referenced; ``free_count + in_use == n_blocks`` always, counting shared
    blocks ONCE; ``high_watermark`` is monotone; a full trace replay
    (everything released/unpinned) restores the exact initial free set.
    """

    def __init__(self, n_blocks: int, n_shards: int = 1,
                 shard_of: Optional[Sequence[int]] = None):
        self.n_blocks = n_blocks
        self.n_shards = max(int(n_shards), 1)
        if shard_of is None:  # contiguous chunks, GSPMD's layout
            shard_of = [(b * self.n_shards) // n_blocks
                        for b in range(n_blocks)]
        self.shard_of = [int(s) for s in shard_of]
        assert len(self.shard_of) == n_blocks
        # Per-shard min-heaps (lowest block index pops first); heap release
        # is O(log n) vs the old per-free re-sort's O(n log n), which went
        # quadratic over a retirement burst.
        self._free: List[List[int]] = [
            [b for b in range(n_blocks) if self.shard_of[b] == i]
            for i in range(self.n_shards)]
        for pool in self._free:
            heapq.heapify(pool)
        self.owner: List[Optional[int]] = [None] * n_blocks  # block -> rid
        self.refcount: List[int] = [0] * n_blocks
        self._held: Dict[int, List[int]] = {}  # rid -> referenced blocks,
        #                                        in logical-block order
        self._reserved: Dict[int, int] = {}    # rid -> outstanding blocks
        self.high_watermark = 0                # peak blocks ever in use
        # bumped whenever capacity may have GROWN (a block freed, a
        # reservation refunded): lets a blocked admission skip retrying —
        # lookup + evict-scan per decode step — until something changed
        self.capacity_version = 0

    # ---- accounting ----
    @property
    def free_count(self) -> int:
        return sum(len(f) for f in self._free)

    @property
    def in_use(self) -> int:
        """Blocks holding live data; a block shared N ways counts once."""
        return self.n_blocks - self.free_count

    @property
    def reserved_total(self) -> int:
        """Outstanding (not yet materialized) reservations."""
        return sum(self._reserved.values())

    def owned_by(self, rid: int) -> List[int]:
        """Blocks ``rid`` references (shared prefix blocks first, then its
        own allocations), in logical-block order."""
        return list(self._held.get(rid, ()))

    def is_shared(self, blk: int) -> bool:
        return self.refcount[blk] > 1

    # ---- lifecycle ----
    def can_reserve(self, n: int) -> bool:
        return n <= self.free_count - self.reserved_total

    def reserve(self, rid: int, n: int) -> None:
        if rid in self._reserved:
            raise ValueError(f"request {rid} already holds a reservation")
        if not self.can_reserve(n):
            raise ValueError(
                f"cannot reserve {n} blocks: {self.free_count} free, "
                f"{self.reserved_total} already promised")
        self._reserved[rid] = n

    def reserved_of(self, rid: int) -> int:
        """Blocks still promised (reserved, not yet allocated) to ``rid``."""
        return self._reserved.get(rid, 0)

    def grow_reserve(self, rid: int, n: int = 1) -> None:
        """Grow ``rid``'s outstanding reservation by ``n`` blocks — the
        optimistic-admission pressure path (DESIGN.md §3 "SLO scheduling"):
        a request admitted on EXPECTED usage that outruns it gets more
        reservation once the engine has freed capacity (eviction or
        preemption).  Same availability gate as ``reserve``."""
        if n <= 0:
            raise ValueError(f"grow_reserve needs n > 0, got {n}")
        if rid not in self._reserved:
            raise ValueError(
                f"request {rid} holds no reservation to grow — grow_reserve "
                f"is for admitted requests only")
        if not self.can_reserve(n):
            raise ValueError(
                f"cannot grow reservation by {n}: {self.free_count} free, "
                f"{self.reserved_total} already promised")
        self._reserved[rid] += n

    def alloc(self, rid: int, shard: Optional[int] = None) -> int:
        """Take one exclusive block for ``rid``, drawing down its
        reservation.  ``shard`` is a placement hint (the slot's data
        shard): honored when that shard has free blocks, else falls back
        to the fullest pool."""
        if self._reserved.get(rid, 0) <= 0:
            raise ValueError(
                f"request {rid} allocating beyond its reservation — "
                f"admission accounting bug")
        if shard is not None and 0 <= shard < self.n_shards \
                and self._free[shard]:
            pool = self._free[shard]
        else:
            pool = max(self._free, key=len)
        if not pool:
            raise ValueError("no free blocks despite reservation — "
                             "allocator invariant broken")
        blk = heapq.heappop(pool)
        self.owner[blk] = rid
        self.refcount[blk] = 1
        self._held.setdefault(rid, []).append(blk)
        self._reserved[rid] -= 1
        self.high_watermark = max(self.high_watermark, self.in_use)
        return blk

    # ---- sharing (prefix cache) ----
    def attach(self, rid: int, blocks: Sequence[int]) -> None:
        """Share already-populated blocks read-only into ``rid`` (a prefix
        cache hit): each gains a reference and joins ``rid``'s held list —
        ahead of any of its own allocations, preserving logical order.
        Validates everything BEFORE the first increment, so a rejected
        attach leaves no stray references behind."""
        if self._held.get(rid):
            raise ValueError(
                f"request {rid} already holds blocks; attach prefix blocks "
                f"before any alloc so logical order is preserved")
        free = [blk for blk in blocks if self.refcount[blk] <= 0]
        if free:
            raise ValueError(
                f"cannot attach free block(s) {free} to request {rid}")
        for blk in blocks:
            self.refcount[blk] += 1
        self._held.setdefault(rid, []).extend(blocks)

    def ref_block(self, blk: int) -> None:
        """Pin a populated block (the prefix cache publishing it)."""
        if self.refcount[blk] <= 0:
            raise ValueError(f"cannot pin free block {blk}")
        self.refcount[blk] += 1

    def unref_block(self, blk: int) -> bool:
        """Drop one pin; returns True when the block was freed."""
        return self._decref(blk)

    def fork(self, rid: int, blk: int) -> int:
        """Copy-on-write: make ``rid``'s reference to ``blk`` exclusive.
        Already-exclusive blocks are returned as-is; a shared block is
        swapped for a fresh allocation (drawing down the reservation) and
        the caller must copy the block's device contents to the returned
        id before writing."""
        held = self._held.get(rid, [])
        if blk not in held:
            raise ValueError(f"block {blk} not referenced by request {rid}")
        if self.refcount[blk] == 1:
            return blk
        new = self.alloc(rid, shard=self.shard_of[blk])
        # keep logical order: the fresh block replaces the shared one
        held.pop()                       # alloc appended it at the end
        held[held.index(blk)] = new
        self._decref(blk)
        return new

    # ---- release ----
    def _decref(self, blk: int) -> bool:
        if self.refcount[blk] <= 0:
            raise ValueError(f"refcount underflow on block {blk}")
        self.refcount[blk] -= 1
        if self.refcount[blk] == 0:
            self.owner[blk] = None
            heapq.heappush(self._free[self.shard_of[blk]], blk)
            self.capacity_version += 1
            return True
        return False

    def release(self, rid: int) -> int:
        """Drop every reference ``rid`` holds (freeing blocks whose LAST
        reference this was — never a block with refs remaining) and the
        unused remainder of its reservation; returns how many blocks were
        actually freed."""
        freed = 0
        for blk in self._held.pop(rid, []):
            if self.owner[blk] == rid:
                self.owner[blk] = None  # survivors belong to their sharers
            freed += bool(self._decref(blk))
        if self._reserved.pop(rid, None):
            self.capacity_version += 1     # reservation refund
        return freed


# ---------------------------------------------------------------------------
# The scheduler proper.
# ---------------------------------------------------------------------------
class Scheduler:
    """Admission of arrived requests into free decode slots — FIFO by
    default, or ordered by an SLO policy's aged-priority key.

    Drive it with a monotonically non-decreasing ``now`` (seconds since serve
    start):

        sched.poll(now)                  # arrivals -> waiting queue
        for slot, req in sched.admit(now): ...prefill + insert...
        ...run one decode step...
        sched.retire(slot, now)          # at EOS / max_new
        sched.preempt(slot, now, ...)    # under pool pressure (SLO mode)

    ``policy`` is any object with a ``sort_key(req)`` callable whose key is
    TIME-INVARIANT (e.g. ``priority + arrival_s / aging_s`` — the relative
    order of two requests never changes as the clock advances), so the
    waiting queue can stay an insertion-sorted list instead of being
    re-sorted every step.  ``None`` means FIFO: key ``(arrival_s, rid)``.
    """

    def __init__(self, requests: Sequence[Request], max_batch: int,
                 n_shards: int = 1,
                 shard_of: Optional[Sequence[int]] = None,
                 blocks: Optional[BlockAllocator] = None,
                 blocks_needed: Optional[Callable[[Request], int]] = None,
                 prefix=None, policy=None):
        for r in requests:
            if r.admit_s is not None or r.tokens:
                raise ValueError(
                    f"request {r.rid} was already served (accounting is "
                    f"mutated in place); build a fresh trace per serve")
        self._pending = deque(sorted(requests,
                                     key=lambda r: (r.arrival_s, r.rid)))
        self.policy = policy
        self._key: Callable[[Request], Tuple] = (
            policy.sort_key if policy is not None
            else (lambda r: (r.arrival_s, r.rid)))
        self.waiting: List[Request] = []
        self.slots = SlotAllocator(max_batch, n_shards, shard_of)
        # Paged cache (DESIGN.md §3): admission additionally gated on block
        # availability — a free slot is not enough, the request's worst-case
        # block count (``blocks_needed``, supplied by the engine since
        # bucketing policy lives there) must be reservable too.
        self.blocks = blocks
        self._blocks_needed = blocks_needed
        if (blocks is None) != (blocks_needed is None):
            raise ValueError("blocks and blocks_needed come as a pair")
        # Prefix cache (DESIGN.md §3 "Prefix cache"): admission looks up the
        # longest cached block-aligned prompt prefix, shares those blocks
        # into the request (shrinking its reservation), and retirement
        # publishes completed prompts' full blocks back into the cache.
        self.prefix = prefix
        if prefix is not None and blocks is None:
            raise ValueError("a prefix cache needs a BlockAllocator")
        self.running: Dict[int, Request] = {}       # slot -> request
        self.finished: List[Request] = []
        # head-of-line block memo: (rid, capacity_version) of the last
        # admission attempt that failed on blocks — retrying is pointless
        # (and, with a prefix cache, re-pays lookup hashing + the eviction
        # scan every decode step) until capacity may have grown
        self._hol_blocked: Optional[Tuple[int, int]] = None

    # ---- queue movement ----
    def poll(self, now: float) -> int:
        """Move requests whose arrival time has passed into the waiting
        queue (policy order; FIFO when no policy).  Returns how many
        arrived."""
        n = 0
        while self._pending and self._pending[0].arrival_s <= now:
            bisect.insort(self.waiting, self._pending.popleft(),
                          key=self._key)
            n += 1
        return n

    def _requeue(self, req: Request) -> None:
        """Put a preempted request back into the waiting queue at its policy
        position.  Its time-invariant sort key is unchanged by preemption,
        so it slots back ahead of anything lower-priority / later-arrived."""
        bisect.insort(self.waiting, req, key=self._key)

    def admit(self, now: float) -> List[Tuple[int, Request]]:
        """Admit waiting requests (policy order) into free slots; returns
        the new (slot, request) assignments for the engine to prefill +
        insert.  For a re-admitted (preempted) request the prefix lookup
        runs over ``full_seq`` — prompt plus everything already generated —
        so a prior publish makes restore a suffix-only re-prefill."""
        admitted = []
        while self.waiting and self.slots.free_count:
            req = self.waiting[0]
            if self.blocks is not None:
                if self._hol_blocked == (req.rid,
                                         self.blocks.capacity_version):
                    break      # nothing changed since the last failure
                hit: List[int] = []
                if self.prefix is not None:
                    hit = self.prefix.lookup(req.full_seq)
                    if hit:
                        # attach BEFORE any eviction attempt: the extra
                        # reference makes the matched entries unevictable
                        self.blocks.attach(req.rid, hit)
                need = self._blocks_needed(req) - len(hit)
                if not self.blocks.can_reserve(need):
                    # LRU-evict unreferenced cache entries to make room
                    if self.prefix is not None:
                        self.prefix.evict_until(self.blocks, need)
                    if not self.blocks.can_reserve(need):
                        if hit:          # roll back the shared references
                            self.blocks.release(req.rid)
                        self._hol_blocked = (req.rid,
                                             self.blocks.capacity_version)
                        break  # head-of-line waits for capacity
                self.blocks.reserve(req.rid, need)
                req.prefix_blocks = list(hit)
                # CUMULATIVE across re-admissions: restore hits are real
                # cache service too (queue_s/ttft_s keep first-admission
                # semantics via the ``admit_s is None`` guard below)
                req.prefix_hit_tokens += (len(hit) * self.prefix.block_size
                                          if self.prefix is not None else 0)
                if self.prefix is not None:
                    self.prefix.note_lookup(hit,
                                            restore=req.preemptions > 0)
            self.waiting.pop(0)
            slot = self.slots.alloc(req.rid)
            req.slot = slot
            if req.admit_s is None:      # FIRST admission only — queue_s
                req.admit_s = now        # must not shrink on re-admission
            self.running[slot] = req
            admitted.append((slot, req))
        return admitted

    def preempt(self, slot: int, now: float,
                covered: Optional[int] = None) -> Request:
        """Evict the request in ``slot`` mid-serve (DESIGN.md §3 "SLO
        scheduling"): publish its pool-resident KV into the prefix cache so
        resume is a suffix-only re-prefill, release its blocks AND its
        outstanding reservation (both observable through
        ``capacity_version``), and re-queue it at its policy position.

        ``covered`` caps how many leading tokens of ``full_seq`` have KV
        actually written in the pool (a decode victim's newest token is
        pending — its KV is unwritten; a mid-chunking victim has only the
        chunks inserted so far).  ``None`` publishes every full block of
        ``full_seq``."""
        req = self.running.pop(slot)
        self.slots.release(slot)
        if self.blocks is not None:
            if self.prefix is not None:
                seq = req.full_seq
                if covered is not None:
                    seq = seq[:covered]
                self.prefix.publish(seq, self.blocks.owned_by(req.rid),
                                    self.blocks)
            self.blocks.release(req.rid)
        req.slot = None
        req.preemptions += 1
        req.prefix_blocks = []
        self._requeue(req)
        return req

    def retire(self, slot: int, now: float) -> Request:
        req = self.running.pop(slot)
        req.finish_s = now
        self.slots.release(slot)
        if self.blocks is not None:
            if self.prefix is not None:
                # publish the completed prompt's full blocks (the cache
                # pins them) before the request's own references drop
                self.prefix.publish(req.prompt,
                                    self.blocks.owned_by(req.rid),
                                    self.blocks)
            self.blocks.release(req.rid)
        self.finished.append(req)
        return req

    # ---- state queries ----
    @property
    def done(self) -> bool:
        return not (self._pending or self.waiting or self.running)

    def next_arrival_s(self) -> Optional[float]:
        return self._pending[0].arrival_s if self._pending else None


# ---------------------------------------------------------------------------
# Metrics.
# ---------------------------------------------------------------------------
def _pctile(vals: np.ndarray, q: float) -> float:
    """Percentile over the finite entries only — unfinished requests report
    NaN accounting (see Request.latency_s) and must not poison the
    aggregate; all-NaN input degrades to 0.0."""
    vals = vals[~np.isnan(vals)]
    return float(np.percentile(vals, q)) if vals.size else 0.0


def _nanmean(vals: np.ndarray) -> float:
    """Mean over the finite entries only, 0.0 when every entry is NaN (the
    spec-off trace: no request ever ran a speculative round).  np.nanmean
    warns on all-NaN slices, so filter explicitly like ``_pctile``."""
    vals = vals[~np.isnan(vals)]
    return float(np.mean(vals)) if vals.size else 0.0


def summarize(requests: Sequence[Request], wall_s: float,
              mode: str = "") -> Dict:
    """Throughput + latency percentiles over a request set (unfinished
    requests contribute tokens but are skipped in the percentiles)."""
    if not requests:
        return {"mode": mode, "n_requests": 0, "tokens": 0, "wall_s": wall_s,
                "tok_per_s": 0.0, "p50_latency_s": 0.0, "p99_latency_s": 0.0,
                "p50_ttft_s": 0.0, "p99_ttft_s": 0.0,
                "p50_itl_s": 0.0, "p99_itl_s": 0.0, "preemptions": 0,
                "accepted_per_step": 0.0, "draft_overhead_s": 0.0}
    lats = np.asarray([r.latency_s for r in requests])
    ttfts = np.asarray([r.ttft_s for r in requests])
    aps = np.asarray([r.accepted_per_step for r in requests])
    # inter-token latency: the pool of ALL consecutive-emission gaps across
    # requests (an SLO is per token, not per request).  0- and 1-token
    # requests contribute an EMPTY gap array — never zeros, which would
    # fraudulently drag p50 down (itl_gaps regression-tests this).
    gaps = (np.concatenate([r.itl_gaps for r in requests])
            if requests else np.empty((0,), np.float64))
    tokens = int(sum(len(r.tokens) for r in requests))
    return {
        "mode": mode,
        "n_requests": len(requests),
        "tokens": tokens,
        "wall_s": wall_s,
        # wall_s == 0 (a degenerate instant trace) used to yield inf, which
        # json.dump writes as bare ``Infinity`` — INVALID JSON that breaks
        # strict parsers of BENCH_serve.json.  0.0 is the honest degenerate.
        "tok_per_s": tokens / wall_s if wall_s > 0 else 0.0,
        "p50_latency_s": _pctile(lats, 50),
        "p99_latency_s": _pctile(lats, 99),
        "p50_ttft_s": _pctile(ttfts, 50),
        "p99_ttft_s": _pctile(ttfts, 99),
        "p50_itl_s": _pctile(gaps, 50),
        "p99_itl_s": _pctile(gaps, 99),
        "preemptions": int(sum(r.preemptions for r in requests)),
        # speculative decoding (0.0 whenever spec is off / no rounds ran):
        # mean accepted draft tokens per round, and total wall seconds the
        # engine spent inside draft passes (the overhead amortized by the
        # accepted tokens)
        "accepted_per_step": _nanmean(aps),
        "draft_overhead_s": float(sum(r.draft_s for r in requests)),
    }
