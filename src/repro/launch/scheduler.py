"""Iteration-level scheduler for the continuous-batching serving engine.

Orca-style continuous batching (DESIGN.md §3): the decode step is a fixed
``(max_batch, 1)`` tensor over ``max_batch`` *slots*; the scheduler owns which
request occupies which slot.  New requests are admitted into free slots
mid-decode, sequences retire at EOS / their own ``max_new`` (freeing the slot
immediately), and a FIFO waiting queue preserves arrival order.  The engine
(``repro.launch.serve``) is the device half; this module is pure host-side
bookkeeping — request queue, Poisson arrival simulation, slot allocation, and
per-request latency accounting — so it is unit-testable without a model.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


# ---------------------------------------------------------------------------
# Requests and arrival traces.
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class Request:
    """One serving request plus its lifecycle accounting (filled in by the
    scheduler/engine as the request moves arrival -> admit -> retire)."""
    rid: int
    prompt: np.ndarray                  # (S,) int32 token ids
    max_new: int                        # per-request generation budget
    arrival_s: float = 0.0              # trace time the request shows up

    # --- engine-filled accounting ---
    admit_s: Optional[float] = None     # admitted into a decode slot
    first_token_s: Optional[float] = None
    finish_s: Optional[float] = None
    slot: Optional[int] = None          # slot the request decoded in
    tokens: List[int] = dataclasses.field(default_factory=list)

    @property
    def latency_s(self) -> float:
        """Arrival -> completion (includes queueing — the p99 that matters)."""
        return (self.finish_s or 0.0) - self.arrival_s

    @property
    def ttft_s(self) -> float:
        """Arrival -> first generated token."""
        return (self.first_token_s or 0.0) - self.arrival_s

    @property
    def queue_s(self) -> float:
        return (self.admit_s or 0.0) - self.arrival_s

    @property
    def out(self) -> np.ndarray:
        return np.asarray(self.tokens, np.int32)


def poisson_trace(n_requests: int, *, rate_rps: float, prompt_len: int,
                  max_new: int, vocab_size: int, seed: int = 0,
                  min_new: Optional[int] = None,
                  prompt_jitter: int = 0) -> List[Request]:
    """Simulated open-loop arrival process: exponential inter-arrival times at
    ``rate_rps`` requests/s, heterogeneous decode budgets in
    ``[min_new, max_new]`` (default min_new: ``max(1, max_new // 4)``; the
    heterogeneity is what a batch-synchronous server pays for — every
    sequence in a static batch runs to the batch max).  Deterministic given
    ``seed``.
    """
    rng = np.random.default_rng(seed)
    min_new = max(1, max_new // 4) if min_new is None else max(1, min_new)
    if min_new > max_new:
        raise ValueError(f"min_new={min_new} exceeds max_new={max_new}")
    reqs, t = [], 0.0
    for i in range(n_requests):
        t += float(rng.exponential(1.0 / rate_rps))
        plen = prompt_len
        if prompt_jitter:
            plen = max(1, prompt_len + int(rng.integers(-prompt_jitter,
                                                        prompt_jitter + 1)))
        prompt = rng.integers(0, vocab_size, size=(plen,)).astype(np.int32)
        reqs.append(Request(rid=i, prompt=prompt,
                            max_new=int(rng.integers(min_new, max_new + 1)),
                            arrival_s=t))
    return reqs


# ---------------------------------------------------------------------------
# Slot allocation.
# ---------------------------------------------------------------------------
class SlotAllocator:
    """Fixed pool of ``n_slots`` decode slots, optionally partitioned into
    per-shard pools.

    On a sharded mesh the Executor lays the slot dim of the decode cache out
    contiguously over the data axes (``sharding.slot_shard_map``); admission
    then balances data-parallel work by taking a free slot from the shard
    with the MOST free slots (ties -> lowest shard index), lowest slot index
    within the shard.  With ``n_shards == 1`` (the single-device no-op path)
    this degenerates to exactly the classic lowest-index-first reuse.
    """

    def __init__(self, n_slots: int, n_shards: int = 1,
                 shard_of: Optional[Sequence[int]] = None):
        self.n_slots = n_slots
        self.n_shards = max(int(n_shards), 1)
        if shard_of is None:  # contiguous chunks, GSPMD's layout
            shard_of = [(s * self.n_shards) // n_slots for s in range(n_slots)]
        self.shard_of = [int(s) for s in shard_of]
        assert len(self.shard_of) == n_slots
        self._free: List[List[int]] = [
            sorted((s for s in range(n_slots) if self.shard_of[s] == i),
                   reverse=True)                          # pop() -> lowest
            for i in range(self.n_shards)]
        self.occupant: List[Optional[int]] = [None] * n_slots  # slot -> rid

    @property
    def free_count(self) -> int:
        return sum(len(f) for f in self._free)

    def free_per_shard(self) -> List[int]:
        return [len(f) for f in self._free]

    def alloc(self, rid: int) -> int:
        shard = max(range(self.n_shards),
                    key=lambda i: (len(self._free[i]), -i))
        slot = self._free[shard].pop()
        self.occupant[slot] = rid
        return slot

    def release(self, slot: int) -> None:
        if self.occupant[slot] is None:
            raise ValueError(f"slot {slot} is already free")
        self.occupant[slot] = None
        pool = self._free[self.shard_of[slot]]
        pool.append(slot)
        pool.sort(reverse=True)


# ---------------------------------------------------------------------------
# The scheduler proper.
# ---------------------------------------------------------------------------
class Scheduler:
    """FIFO admission of arrived requests into free decode slots.

    Drive it with a monotonically non-decreasing ``now`` (seconds since serve
    start):

        sched.poll(now)                  # arrivals -> waiting queue
        for slot, req in sched.admit(now): ...prefill + insert...
        ...run one decode step...
        sched.retire(slot, now)          # at EOS / max_new
    """

    def __init__(self, requests: Sequence[Request], max_batch: int,
                 n_shards: int = 1,
                 shard_of: Optional[Sequence[int]] = None):
        for r in requests:
            if r.admit_s is not None or r.tokens:
                raise ValueError(
                    f"request {r.rid} was already served (accounting is "
                    f"mutated in place); build a fresh trace per serve")
        self._pending = deque(sorted(requests,
                                     key=lambda r: (r.arrival_s, r.rid)))
        self.waiting: deque = deque()
        self.slots = SlotAllocator(max_batch, n_shards, shard_of)
        self.running: Dict[int, Request] = {}       # slot -> request
        self.finished: List[Request] = []

    # ---- queue movement ----
    def poll(self, now: float) -> int:
        """Move requests whose arrival time has passed into the waiting
        queue (arrival order).  Returns how many arrived."""
        n = 0
        while self._pending and self._pending[0].arrival_s <= now:
            self.waiting.append(self._pending.popleft())
            n += 1
        return n

    def admit(self, now: float) -> List[Tuple[int, Request]]:
        """Admit waiting requests (FIFO) into free slots; returns the new
        (slot, request) assignments for the engine to prefill + insert."""
        admitted = []
        while self.waiting and self.slots.free_count:
            req = self.waiting.popleft()
            slot = self.slots.alloc(req.rid)
            req.slot = slot
            req.admit_s = now
            self.running[slot] = req
            admitted.append((slot, req))
        return admitted

    def retire(self, slot: int, now: float) -> Request:
        req = self.running.pop(slot)
        req.finish_s = now
        self.slots.release(slot)
        self.finished.append(req)
        return req

    # ---- state queries ----
    @property
    def done(self) -> bool:
        return not (self._pending or self.waiting or self.running)

    def next_arrival_s(self) -> Optional[float]:
        return self._pending[0].arrival_s if self._pending else None


# ---------------------------------------------------------------------------
# Metrics.
# ---------------------------------------------------------------------------
def summarize(requests: Sequence[Request], wall_s: float,
              mode: str = "") -> Dict:
    """Throughput + latency percentiles over a finished request set."""
    if not requests:
        return {"mode": mode, "n_requests": 0, "tokens": 0, "wall_s": wall_s,
                "tok_per_s": 0.0, "p50_latency_s": 0.0, "p99_latency_s": 0.0,
                "p50_ttft_s": 0.0, "p99_ttft_s": 0.0}
    lats = np.asarray([r.latency_s for r in requests])
    ttfts = np.asarray([r.ttft_s for r in requests])
    tokens = int(sum(len(r.tokens) for r in requests))
    return {
        "mode": mode,
        "n_requests": len(requests),
        "tokens": tokens,
        "wall_s": wall_s,
        "tok_per_s": tokens / wall_s if wall_s else float("inf"),
        "p50_latency_s": float(np.percentile(lats, 50)),
        "p99_latency_s": float(np.percentile(lats, 99)),
        "p50_ttft_s": float(np.percentile(ttfts, 50)),
        "p99_ttft_s": float(np.percentile(ttfts, 99)),
    }
