"""Continuous-batching serving engine over PSI-quantized weights.

The engine owns ``max_batch`` decode *slots* backed by one typed ``KVCache``
(DESIGN.md §3).  Under the default **paged** layout (full-attention
families) the cache is a pool of fixed-size blocks driven by host-side
block tables: the scheduler's ``BlockAllocator`` reserves a request's
worst-case blocks at admission, materializes them on demand during decode,
and frees them at retirement — so admission is gated on *actual* token
capacity instead of worst-case slots, and heterogeneous-length traffic fits
more concurrent requests in the same cache bytes (``--cache-layout`` /
``--block-size`` / ``--cache-blocks``).  The **dense** layout (per-slot
``max_seq`` slabs) remains for recurrent/SSM state, SWA rings, and encdec.

A slot-based scheduler (``repro.launch.scheduler``) admits arriving
requests into free slots mid-decode, retires sequences at EOS / ``max_new``
(freeing slot AND blocks immediately for the next arrival), and the engine
interleaves prefill of admissions with ongoing decode steps.  The jitted
decode step is shape-stable — a fixed ``(max_batch, 1)`` token tensor, an
active-slot mask that freezes the cache rows of free slots, and (paged) a
``(max_batch, n_bt)`` block-table input — so XLA compiles it exactly once
per serve lifetime (DESIGN.md §3).  The decode step runs entirely on the
PSI serving format — on TPU the psi_matmul Pallas kernel reads 5/8-bit
weights from HBM (DESIGN.md §2).

The Server is the HOST half only: scheduler loop, prompt buckets, latency
accounting.  Every device interaction — mesh construction, sharded
placement, jit compilation + donation — lives in the mesh-native
``repro.runtime.Executor`` (DESIGN.md §5); there is exactly one compilation
path whether the engine runs on 1 device or a pod.  On a sharded mesh the
decode slots are laid out contiguously over the "data" axis and the
scheduler admits into per-shard free slots.

Under ``--slo`` (DESIGN.md §3 "SLO scheduling") the scheduler orders
admission by an aged-priority policy (``repro.launch.slo``), reservation
turns OPTIMISTIC (expected usage instead of worst case), and pool pressure
is resolved by PREEMPTING the lowest-priority running request — its
pool-resident KV is published into the prefix cache so resume is a cheap
suffix-only re-prefill (the COW machinery as a swap layer).
``--prefill-chunk N`` splits long prompt prefills into N-token chunks
interleaved with decode steps, reusing the prefix path's ``pos0``/``ctx_kv``
absolute-position machinery so chunk N attends over the pool-resident KV of
chunks 0..N-1; intermediate chunks skip the lm-head.  Both keep the decode
step compiling exactly once and the emitted tokens identical to the FIFO
baseline.

A batch-synchronous ("static") mode runs the same machinery with admission
barriered until every slot drains — the baseline ``benchmarks/serve_bench.py``
measures continuous batching against.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b --reduced \\
      --quant psi8 --requests 32 --max-batch 4 --arrival-rate 1000 \\
      --max-new 48 --mode both --mesh 1x1
"""
from __future__ import annotations

import argparse
import dataclasses
import math
import time
from typing import Dict, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.configs import get_config, reduced_config
from repro.core.quantizer import (fake_quant_param_tree, parse_policy,
                                  parse_quant_mode, serving_mode_choices)
from repro.launch.mesh import make_mesh
from repro.launch.prefix_cache import PrefixCache
from repro.launch.scheduler import (BlockAllocator, Request, Scheduler,
                                    poisson_trace, replay_round, summarize)
from repro.launch.slo import parse_slo_spec, slo_report
from repro.models import build_model, kvcache as kvc
from repro.perf.roofline_model import PEAK_FLOPS, decode_macs_per_token
from repro.runtime.executor import Executor

# Prompt lengths are rounded up to a multiple of this before prefill so the
# number of compiled prefill shapes is bounded (attention caches mask the pad
# slots out via true_lens; recurrent families prefill at exact length).
PREFILL_BUCKET = 16


def parse_spec_spec(spec: Optional[str]) -> Optional[Tuple[int, int]]:
    """"BITS:K" (e.g. "3:4") -> (draft_bits, k) for --speculative; None /
    "off" -> None.  BITS must name a registered PsiFormat narrower than the
    serving width (validated downstream where the serving format is known);
    K is the draft length per round."""
    if not spec or spec == "off":
        return None
    try:
        bits, k = (int(p) for p in spec.split(":"))
    except ValueError as e:
        raise ValueError(
            f"malformed --speculative spec {spec!r}: want \"BITS:K\" with "
            f"two integers, e.g. \"3:4\" (psi3 draft, 4 tokens/round)") from e
    if k < 1:
        raise ValueError(f"--speculative draft length k={k} must be >= 1")
    return bits, k


def parse_mesh_spec(spec: Optional[str]):
    """"DxM" (e.g. "1x1", "4x2") -> a (data, model) Mesh; None / "1x1" with
    one device -> None (the Executor's single-device path)."""
    if not spec or spec == "1x1":
        return None
    try:
        d, m = (int(p) for p in spec.lower().split("x"))
    except ValueError as e:
        # a bare "8" or a "2x2x2" used to surface as an opaque unpacking
        # ValueError; say what shape the spec must have
        raise ValueError(
            f"malformed mesh spec {spec!r}: want \"DATAxMODEL\" with two "
            f"integer extents, e.g. \"1x1\" or \"4x2\"") from e
    if d * m > len(jax.devices()):
        raise ValueError(
            f"mesh {spec} needs {d * m} devices, have {len(jax.devices())} "
            f"(set XLA_FLAGS=--xla_force_host_platform_device_count=N on "
            f"CPU)")
    return make_mesh((d, m), ("data", "model"))


class Server:
    """Slot-based serving engine: continuous or batch-synchronous scheduling
    over one shape-stable jitted decode step (DESIGN.md §3).  Device work is
    delegated to a mesh-native Executor (DESIGN.md §5)."""

    def __init__(self, cfg, params, max_batch: int = 4, max_seq: int = 256,
                 eos_id: int = -1, bucket: int = PREFILL_BUCKET, mesh=None,
                 executor: Optional[Executor] = None,
                 n_blocks: Optional[int] = None,
                 speculative: Optional[Tuple[int, int]] = None,
                 prefill_chunk: int = 0, slo=None,
                 decode_horizon: int = 1, watts: float = 215.0):
        self.cfg = cfg
        self.paged = cfg.resolved_cache_layout == kvc.PAGED
        # Multi-step decode (DESIGN.md §3 "Multi-step decode & host
        # overlap"): horizon-M rounds of the on-device token loop; 1 = the
        # classic step-at-a-time path.  ``watts`` is the CLI stand-in board
        # power for the tokens-per-joule stat (default: a TPU v5e-class
        # figure, matching the roofline's PEAK_FLOPS denominator).
        self.decode_horizon = int(decode_horizon or 1)
        if self.decode_horizon < 1:
            raise ValueError(
                f"decode_horizon={decode_horizon} must be >= 1")
        self.watts = float(watts)
        # Self-speculative decoding (DESIGN.md §"Self-speculative decoding"):
        # (draft_bits, k) or None.  The Executor validates the deep
        # preconditions (paged layout, k <= block_size, quantized params);
        # the Server only tracks the +k-1 cache/block overhang a round's
        # k-wide write needs past the last emitted token.
        self.spec = tuple(speculative) if speculative else None
        self.spec_k = self.spec[1] if self.spec else 0
        self._spec_overhang = self.spec_k - 1 if self.spec else 0
        if self.decode_horizon > 1 and self.spec:
            raise ValueError(
                "--decode-horizon > 1 does not compose with --speculative: "
                "a speculative round is already a fused multi-token device "
                "unit with its own acceptance loop — pick ONE multi-token "
                "decode strategy (drop --speculative or set the horizon "
                "to 1)")
        # Shared-prefix block reuse (DESIGN.md §3 "Prefix cache"):
        # validated here so an impossible combination (dense layout, mrope)
        # fails at construction, not mid-serve.
        self.prefix_enabled = cfg.prefix_cache_enabled
        if n_blocks is not None and not self.paged:
            raise ValueError(
                "n_blocks/--cache-blocks only applies to the paged cache "
                "layout; this server resolved to dense "
                "(cfg.resolved_cache_layout)")
        self.block_size = cfg.cache_block_size if self.paged else 0
        if self.paged:
            # Align the cache extent to the block grid: the paged read
            # attends over n_bt * block_size key columns, and keeping that
            # equal to the dense extent keeps the two layouts bit-identical
            # (same reduction shapes) for the layout-equivalence tests.
            max_seq = -(-max_seq // self.block_size) * self.block_size
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.eos_id = eos_id
        self.bucket = bucket
        # SLO scheduling + chunked prefill (DESIGN.md §3 "SLO scheduling").
        # Both lean on the prefix path's pos0/ctx_kv machinery — absolute
        # positions replayed from a scalar offset — so they carry the same
        # paged + plain-RoPE requirement the prefix cache does.
        self.slo = slo
        self.prefill_chunk = int(prefill_chunk or 0)
        if self.prefill_chunk < 0:
            raise ValueError(
                f"prefill_chunk must be >= 0, got {prefill_chunk}")
        if self.slo is not None or self.prefill_chunk:
            what = ("--slo" if self.slo is not None else "--prefill-chunk")
            if not self.paged:
                raise ValueError(f"{what} requires the paged cache layout "
                                 f"(cfg.resolved_cache_layout)")
            if cfg.rope != "rope":
                raise ValueError(
                    f"{what} replays absolute positions from a block-"
                    f"aligned offset, which needs plain RoPE; got "
                    f"rope={cfg.rope!r}")
        if self.prefill_chunk:
            # chunk boundaries must land on BOTH grids: the block grid (so
            # ctx_ids covers whole blocks) and the prefill bucket grid (so
            # the final piece's bucketed extent never outruns the
            # bucket(full_seq) reservation: pos0 + bucket(L - pos0) ==
            # bucket(L) only for bucket-aligned pos0)
            grid = math.lcm(self.block_size, self.bucket)
            self.prefill_chunk = -(-self.prefill_chunk // grid) * grid
        # every admission goes through the per-request ctx prefill path
        # (nctx=0 compiles its own shape, identical graph) whenever any of
        # the three ctx consumers is on
        self._ctx_serving = (self.prefix_enabled or self.slo is not None
                             or self.prefill_chunk > 0)
        if executor is not None:
            if mesh is not None:
                raise ValueError("pass mesh= OR executor= (the executor "
                                 "already owns its mesh), not both")
            if (executor.max_batch, executor.max_seq) != (max_batch, max_seq):
                raise ValueError(
                    f"injected executor was built for max_batch="
                    f"{executor.max_batch}, max_seq={executor.max_seq}; "
                    f"Server asked for {max_batch}/{max_seq}")
            if executor.speculative != self.spec:
                raise ValueError(
                    f"injected executor was built with speculative="
                    f"{executor.speculative}; Server asked for {self.spec}")
            if executor.decode_horizon != self.decode_horizon:
                raise ValueError(
                    f"injected executor was built with decode_horizon="
                    f"{executor.decode_horizon}; Server asked for "
                    f"{self.decode_horizon}")
        self.executor = executor if executor is not None else Executor(
            cfg, params, max_batch=max_batch, max_seq=max_seq, mesh=mesh,
            n_blocks=n_blocks if self.paged else None,
            speculative=self.spec, decode_horizon=self.decode_horizon)
        self.cache_bytes = kvc.cache_nbytes(jax.eval_shape(
            self.executor._init_cache_fn))
        # Recurrent state absorbs pad tokens, so SSM/hybrid (and whisper's
        # decoder) prefill at exact prompt length instead of padded buckets.
        self._pad_ok = cfg.family not in ("ssm", "hybrid", "encdec")
        self._swa_window = (cfg.window if cfg.attn_type == "swa" else 0)
        # actual KV ring extent (init_kv_cache caps SWA caches at the window)
        self._ring_extent = (min(max_seq, self._swa_window)
                             if self._swa_window else max_seq)

    # -------------------------------------------------------------- plumbing
    def _blocks_needed(self, req: Request) -> int:
        """Pool blocks to reserve at admission, stated over ``full_seq``
        (prompt plus everything generated — a preempted request's restore
        re-reserves what the restore actually needs, not the original
        prompt's worst case).

        FIFO default: the WORST case — the bucketed prefill extent or the
        sequence+remaining-budget extent, whichever is longer — so a
        running request can never starve mid-decode (early EOS returns the
        unused tail).  Speculative rounds are k positions wide regardless
        of remaining budget, so the last round can write up to k-1
        positions past the final emitted token — the overhang joins the
        reservation.

        Under an SLO policy reservation is OPTIMISTIC (DESIGN.md §3 "SLO
        scheduling"): the full prefill extent plus only ``reserve_frac``
        of the remaining budget.  The shortfall is paid on demand via
        ``grow_reserve``, with preemption as the pressure valve — that is
        the whole point: worst-case gating is what head-of-line-blocks a
        bursty heavy tail."""
        L = len(req.full_seq)
        remaining = max(req.max_new - len(req.tokens), 0)
        need = max(self._bucket_len(L),
                   L + remaining + self._spec_overhang)
        if self.slo is not None:
            expected = max(self._bucket_len(L),
                           L + math.ceil(self.slo.reserve_frac * remaining)
                           + self._spec_overhang)
            need = min(need, expected)
        return kvc.blocks_for(need, self.block_size)

    def _block_pref(self, slot: int) -> Optional[int]:
        """Allocate a slot's blocks from its own data shard when the block
        pools partition the same way the slots do (keeps the decode gather
        shard-local); otherwise let the allocator balance."""
        ex = self.executor
        if ex.n_block_shards == ex.n_slot_shards:
            return int(ex.slot_shards[slot])
        return None

    def _bucket_len(self, n: int) -> int:
        if not self._pad_ok:
            return n
        sb = -(-n // self.bucket) * self.bucket
        # Sliding-window ring cache: pad positions past the ring extent
        # (min(window, max_seq)) would evict *real* prompt tokens from the
        # tail window, so fall back to the exact length whenever the padded
        # prompt would overrun it.
        if self._swa_window and sb > self._ring_extent:
            return n
        return sb

    def _prefill_admits(self, cache, admits: Sequence[Tuple[int, Request]],
                        sched: Optional[Scheduler] = None, bt=None,
                        chunking: Optional[Dict[int, int]] = None):
        """Prefill newly admitted requests and insert each into its slot.

        A single admission (the continuous steady state) runs a (1, Sb)
        prefill; a burst (static mode / startup) pads the batch dimension to
        ``max_batch`` and prefills all rows at once, so both engines pay one
        compile per prompt bucket for each of the two batch shapes.
        Returns the first greedy token per admission, aligned with `admits`
        — an entry is None when chunked prefill deferred the slot (it is in
        ``chunking`` state and emits nothing yet).

        Paged layout: each admission's prompt blocks are allocated here
        (drawing down the reservation made at admission) and written into
        the host block table ``bt``; the insert scatters the prefilled rows
        into exactly those blocks (a burst's shared padding beyond a row's
        own allocation routes to the slot's scratch block).

        Ctx serving (prefix cache / SLO / chunked prefill): every admission
        runs the fused suffix-prefill path individually (hits and restore
        depths are per-request — nctx varies — so the padded burst cannot
        batch them), sharing the hit's blocks read-only into the table and
        prefilling only the uncached suffix of ``full_seq``.
        """
        if self._ctx_serving:
            firsts = []
            for slot, req in admits:
                f, cache = self._begin_fill(cache, slot, req, sched, bt,
                                            chunking)
                firsts.append(f)
            return firsts, cache
        lens = [len(r.prompt) for _, r in admits]
        sb = self._bucket_len(max(lens))
        if self.paged:
            for slot, req in admits:
                nb = kvc.blocks_for(self._bucket_len(len(req.prompt)),
                                    self.block_size)
                pref = self._block_pref(slot)
                bt[slot, :] = -1
                for j in range(nb):
                    bt[slot, j] = sched.blocks.alloc(req.rid, shard=pref)
        if not self._swa_window and not self.cfg.is_attention_free:
            # Full-attention cache extent: a longer prefill — or a decode
            # that runs past max_seq — would wrap the ring and silently
            # evict prompt tokens the causal mask still expects.  (SWA is
            # exempt — rolling the window is its defined semantics — and so
            # are attention-free SSMs, whose state is constant-size.)
            need = max(sb, *(len(r.prompt) + r.max_new + self._spec_overhang
                             for _, r in admits))
            if need > self.max_seq:
                raise ValueError(
                    f"request needs cache extent {need} (bucketed prompt + "
                    f"max_new) but Server was built with max_seq="
                    f"{self.max_seq}; size the Server for the longest "
                    f"request")
        # Right-padding a shorter row to sb is only safe when the pads are
        # maskable: never for recurrent state (_pad_ok False), and not for a
        # SWA ring the padded length would overrun (real tokens of shorter
        # rows would roll out of the window).  Otherwise, one per request.
        pad_safe = self._pad_ok and not (self._swa_window
                                         and sb > self._ring_extent)
        if len(set(lens)) > 1 and not pad_safe:
            firsts = []
            for slot, req in admits:
                f, cache = self._prefill_admits(cache, [(slot, req)],
                                                sched, bt)
                firsts.extend(f)
            return firsts, cache
        for _, req in admits:            # leaf call: the prefill really runs
            req.prefilled_tokens += len(req.prompt)
        B = 1 if len(admits) == 1 else self.max_batch
        toks = np.zeros((B, sb), np.int32)
        tl = np.ones((B,), np.int32)
        for i, (_, req) in enumerate(admits):
            toks[i, :len(req.prompt)] = req.prompt
            tl[i] = len(req.prompt)
        if len(admits) == 1:                     # fused prefill + insert
            slot = admits[0][0]
            row = bt[slot] if self.paged else None
            first, cache = self.executor.prefill_insert(toks, tl, cache,
                                                        slot, block_row=row)
            return [int(first[0])], cache
        first, seq_cache = self.executor.prefill(toks, tl)
        first = np.asarray(first)
        slots = np.zeros((self.max_batch,), np.int32)
        valid = np.zeros((self.max_batch,), bool)
        rows = (np.full((self.max_batch, self.executor.n_bt), -1, np.int32)
                if self.paged else None)
        for i, (slot, _) in enumerate(admits):
            slots[i] = slot
            valid[i] = True
            if self.paged:
                rows[i] = bt[slot]
        cache = self.executor.insert_burst(cache, seq_cache, slots, valid,
                                           block_rows=rows)
        return [int(first[i]) for i in range(len(admits))], cache

    def _begin_fill(self, cache, slot, req, sched, bt,
                    chunking: Optional[Dict[int, int]] = None):
        """Start filling a slot's KV for one (re-)admission on the ctx
        path (prefix cache / SLO / chunked prefill): the lookup hit's
        blocks enter the table read-only (shared references held by the
        scheduler), then either the whole remaining suffix prefills now
        (emitting the next token) or — chunked prefill, suffix longer than
        one chunk — the slot enters ``chunking`` state and the engine
        advances it one chunk per loop iteration, interleaved with decode.

        The suffix is ``full_seq[pos0:]``.  For a fresh admission that is
        the uncached prompt tail.  For a preempted request it ends with
        the PENDING token (the newest emitted token, whose KV the decode
        step never wrote), so the final piece's last-position logits ARE
        the next decode output — restore emits exactly the token plain
        decode would have (DESIGN.md §3 "SLO scheduling")."""
        pos0 = len(req.prefix_blocks) * self.block_size
        bt[slot, :] = -1
        if req.prefix_blocks:
            bt[slot, :len(req.prefix_blocks)] = req.prefix_blocks
        if (self.prefill_chunk
                and len(req.full_seq) - pos0 > self.prefill_chunk):
            chunking[slot] = pos0
            return None, cache
        return self._fill_piece(cache, slot, req, sched, bt, pos0)

    def _fill_piece(self, cache, slot, req, sched, bt, cur: int):
        """Prefill + insert one contiguous piece of ``full_seq`` starting
        at the block- and bucket-aligned offset ``cur``, attending over
        the pool-resident KV of ``[0, cur)`` via ``ctx_ids`` at true
        absolute positions.  A non-final piece is exactly ``prefill_chunk``
        tokens with the lm-head skipped (emit=False — nothing to emit);
        the final piece is the bucketed remainder and returns the next
        greedy token.  Fresh blocks draw down the admission reservation —
        and because ``cur`` is bucket-aligned, total coverage is exactly
        ``bucket(len(full_seq))``, never past it."""
        seq = req.full_seq
        bs = self.block_size
        rem = len(seq) - cur
        final = not (self.prefill_chunk and rem > self.prefill_chunk)
        n = self._bucket_len(rem) if final else self.prefill_chunk
        take = rem if final else n
        pref = self._block_pref(slot)
        for j in range(cur // bs, kvc.blocks_for(cur + n, bs)):
            if bt[slot, j] < 0:
                bt[slot, j] = sched.blocks.alloc(req.rid, shard=pref)
        toks = np.zeros((1, n), np.int32)
        toks[0, :take] = seq[cur:cur + take]
        tl = np.asarray([take], np.int32)
        req.prefilled_tokens += int(take)
        first, cache = self.executor.prefill_insert(
            toks, tl, cache, slot, block_row=bt[slot],
            ctx_ids=bt[slot, :cur // bs], emit=final)
        return (int(first[0]) if final else None), cache

    def _advance_chunk(self, cache, slot, sched, bt,
                       chunking: Dict[int, int]):
        """Advance one chunking slot by one piece; returns (first | None,
        cache) — non-None means the final piece ran and the slot is ready
        to decode."""
        req = sched.running[slot]
        cur = chunking[slot]
        first, cache = self._fill_piece(cache, slot, req, sched, bt, cur)
        if first is None:
            chunking[slot] = cur + self.prefill_chunk
        else:
            del chunking[slot]
        return first, cache

    def warmup(self, requests: Sequence[Request], verbose: bool = True) -> int:
        """Compile every shape the trace CAN reach (per prompt bucket: the
        fused single-admission prefill+insert, plus — only when the trace
        can ever co-admit two requests — the max_batch burst prefill + row
        insert, plus the decode step) against a throwaway cache, so serving
        measures steady-state latency, not XLA.

        A single-request trace (or a max_batch=1 engine) can never take the
        burst path, so its shapes are skipped instead of paying their
        compiles up front.  Returns the number of compiled shapes (also
        logged, so compile-count regressions are visible in serve output).
        """
        ex = self.executor
        if self._ctx_serving:
            return self._warmup_ctx(requests, verbose)
        buckets = sorted({self._bucket_len(len(r.prompt)) for r in requests})
        # Burst admission needs >= 2 requests waiting at once; a 1-request
        # trace provably cannot reach those shapes.
        burst_reachable = len(requests) > 1 and self.max_batch > 1
        cache = ex.init_cache()
        n_shapes = 0
        brow = (np.full((ex.n_bt,), -1, np.int32) if self.paged else None)
        for sb in buckets:
            # single admission: fused prefill+insert (the only B=1 path)
            toks1 = np.zeros((1, sb), np.int32)
            tl1 = np.ones((1,), np.int32)
            _, cache = jax.block_until_ready(
                ex.prefill_insert(toks1, tl1, cache, 0, block_row=brow))
            n_shapes += 1
            if burst_reachable:
                # admission burst: batched prefill + one scatter insert
                toksB = np.zeros((self.max_batch, sb), np.int32)
                tlB = np.ones((self.max_batch,), np.int32)
                _, seq_cache = jax.block_until_ready(ex.prefill(toksB, tlB))
                slots = np.arange(self.max_batch, dtype=np.int32)
                rows = (np.full((self.max_batch, ex.n_bt), -1, np.int32)
                        if self.paged else None)
                cache = ex.insert_burst(cache, seq_cache, slots,
                                        np.zeros((self.max_batch,), bool),
                                        block_rows=rows)
                n_shapes += 1
        if burst_reachable:
            # the burst insert compiles per bucket only when the prefilled
            # seq cache's extent follows the bucket (paged); dense prefills
            # at cache_len=max_seq, so one insert executable covers all
            n_shapes += len(buckets) if self.paged else 1
        n_shapes += self._warm_decode(cache)
        if verbose:
            skipped = 0 if burst_reachable else 2 * len(buckets)
            print(f"[warmup] compiled {n_shapes} shapes "
                  f"({len(buckets)} prompt bucket(s), layout "
                  f"{'paged' if self.paged else 'dense'}"
                  + (f", skipped {skipped} unreachable burst shape(s)"
                     if skipped else "") + ")")
        return n_shapes

    def _warm_decode(self, cache) -> int:
        """Compile the decode-side step(s) against a throwaway cache and
        return how many shapes that took.  Plain engine: the single
        shape-stable decode step.  Speculative engine: the fused draft scan
        plus the k-token verify — and the compile contract (exactly those
        TWO executables, the plain decode step never traced) is asserted
        here so a shape regression fails loudly at warmup, not as a silent
        slowdown in a benchmark diff."""
        ex = self.executor
        B = self.max_batch
        tok = np.zeros((B, 1), np.int32)
        act = np.zeros((B,), bool)
        bt = (np.full((B, ex.n_bt), -1, np.int32) if self.paged else None)
        if self.decode_horizon > 1:
            # multi-step engine: the horizon-M round is THE decode shape;
            # the single-step twin must never trace (same contract shape as
            # the speculative pair below)
            rem = np.zeros((B,), np.int32)
            jax.block_until_ready(ex.decode_multi(
                tok, tok, act, rem, cache, block_table=bt,
                eos_id=self.eos_id))
            sizes = ex.multi_cache_sizes()
            if sizes != {"decode_multi": 1, "decode": 0}:
                raise RuntimeError(
                    f"multi-step compile contract violated at warmup: want "
                    f"exactly one horizon-{self.decode_horizon} round "
                    f"executable with the single-step decode untraced, got "
                    f"{sizes}")
            return 1
        if not self.spec:
            jax.block_until_ready(ex.decode(tok, tok, act, cache,
                                            block_table=bt))
            return 1
        drafts, cache = jax.block_until_ready(
            ex.draft(tok, tok, act, cache, bt))
        jax.block_until_ready(ex.verify(tok, drafts, tok, act, cache, bt))
        sizes = ex.spec_cache_sizes()
        if sizes != {"draft": 1, "verify": 1, "decode": 0}:
            raise RuntimeError(
                f"speculative compile contract violated at warmup: want "
                f"exactly one draft + one verify executable with the plain "
                f"decode step untraced, got {sizes}")
        return 2

    def _warmup_ctx(self, requests: Sequence[Request],
                    verbose: bool) -> int:
        """Warmup for ctx serving (prefix cache / SLO / chunked prefill):
        every admission takes the per-request ctx prefill path, so compile,
        per distinct prompt length, the COLD admission's piece ladder —
        each intermediate chunk at ``(prefill_chunk, depth, emit=False)``,
        then the final bucketed piece — and, when organic prefix hits are
        possible, the deepest reachable hit (the longest block-aligned
        proper prefix, at the suffix's bucket).  Intermediate hit depths
        and preemption-restore shapes (suffix over prompt + GENERATED
        tokens — runtime state warmup cannot foresee) compile lazily
        mid-serve.  The decode step is shared with the non-ctx engine and
        still compiles exactly once."""
        ex = self.executor
        # the deepest REACHABLE hit must mirror PrefixCache's caps: keep
        # >= 1 suffix token AND land pos0 on the prefill-bucket grid
        step = PrefixCache.hit_alignment_step(self.block_size, self.bucket)
        bs = self.block_size
        shapes = set()                      # (seq_len, ctx_depth, emit)
        for r in requests:
            L = len(r.prompt)
            cur = 0
            while self.prefill_chunk and L - cur > self.prefill_chunk:
                shapes.add((self.prefill_chunk, cur // bs, False))
                cur += self.prefill_chunk
            shapes.add((self._bucket_len(L - cur), cur // bs, True))
            if self.prefix_enabled or self.slo is not None:
                nmax = ((L - 1) // bs // step) * step
                rem = L - nmax * bs
                if nmax and not (self.prefill_chunk
                                 and rem > self.prefill_chunk):
                    shapes.add((self._bucket_len(rem), nmax, True))
        cache = ex.init_cache()
        n_shapes = 0
        for sb, nctx, em in sorted(shapes):
            toks1 = np.zeros((1, sb), np.int32)
            tl1 = np.ones((1,), np.int32)
            brow = np.full((ex.n_bt,), -1, np.int32)
            _, cache = jax.block_until_ready(
                ex.prefill_insert(toks1, tl1, cache, 0, block_row=brow,
                                  ctx_ids=np.zeros((nctx,), np.int32),
                                  emit=em))
            n_shapes += 1
        n_shapes += self._warm_decode(cache)
        if verbose:
            print(f"[warmup] compiled {n_shapes} shapes "
                  f"({len(shapes)} (len, ctx-depth, emit) triple(s), "
                  f"layout paged + ctx serving)")
        return n_shapes

    def _spec_round(self, sched, cache, tok, pos, act, bt, now_fn):
        """One self-speculative round (DESIGN.md §"Self-speculative
        decoding"): a fused k-step draft pass at the low-bit view of the
        serving checkpoint, then ONE k-token verify at the target width.
        Per slot, accept the longest draft prefix the target agrees with
        (a) and emit min(a+1, k) tokens — the accepted drafts plus the
        target's correction verdict, which IS the plain-decode token for
        that position, so a=0 degrades to exactly non-speculative output.
        Emission is capped at the request's remaining budget and truncated
        at the first EOS; ``pos`` advances by the emitted count only, so
        rejected-tail cache entries sit strictly at/above the next feed
        position and are overwritten by the next round before any query can
        causally read them (no rollback pass)."""
        ex = self.executor
        K = self.spec_k
        t_draft = time.perf_counter()
        drafts_dev, cache = ex.draft(tok, pos, act, cache, bt)
        # the verify window is assembled on device from the draft output,
        # so both dispatches enqueue back-to-back with no host round-trip
        verdicts, cache = ex.verify(tok, drafts_dev, pos, act, cache, bt)
        drafts = np.asarray(drafts_dev)
        # drafts materialize as soon as the draft executable finishes (the
        # verify is merely queued behind it), so this measures the round's
        # draft side; the verify sync below is the target-model cost any
        # decode engine pays
        draft_dt = time.perf_counter() - t_draft
        verdicts = np.asarray(verdicts)
        now = now_fn()
        share = draft_dt / max(int(act.sum()), 1)
        for slot in list(sched.running):
            if not act[slot]:
                continue        # chunking slot: masked out of the round
            req = sched.running[slot]
            req.draft_s += share
            d, v = drafts[slot], verdicts[slot]
            a = 0
            while a < K and d[a] == v[a]:
                a += 1
            emit = [int(x) for x in d[:a]]
            if a < K:
                emit.append(int(v[a]))
            req.spec_rounds += 1
            req.spec_accepted += a
            finished = False
            n_emit = 0
            for t in emit:
                req.emit(t, now)
                n_emit += 1
                if t == self.eos_id or len(req.tokens) >= req.max_new:
                    finished = True
                    break
            pos[slot, 0] += n_emit
            if finished:
                act[slot] = False
                sched.retire(slot, now)
                bt[slot, :] = -1
            else:
                tok[slot, 0] = emit[n_emit - 1]
        return cache

    # ------------------------------------------------------------- the loop
    def serve(self, requests: Sequence[Request], continuous: bool = True,
              warmup: bool = True):
        """Serve an arrival trace; returns (finished requests, stats).

        ``continuous=False`` barriers admission until all slots are free —
        classic batch-synchronous serving over the identical jitted step, so
        benchmark deltas isolate the scheduling policy.  Arrival times are
        interpreted on the wall clock, starting when this call begins.
        """
        clock = time.perf_counter
        ex = self.executor

        def worst_extent(r: Request) -> int:
            # Under an SLO policy a preempted request can restore prompt +
            # generated in one bucketed re-prefill, so the worst cache
            # extent is the BUCKETED full sequence, not max(bucketed
            # prompt, exact sequence).  This is also what guarantees the
            # preemption pressure path terminates: every request is
            # individually feasible, so preempting down to one runner
            # always makes progress.
            if self.slo is not None:
                return self._bucket_len(len(r.prompt) + r.max_new
                                        + self._spec_overhang)
            return max(self._bucket_len(len(r.prompt)),
                       len(r.prompt) + r.max_new + self._spec_overhang)

        if not (self._swa_window or self.cfg.is_attention_free):
            # fail fast, before any request is served/mutated, rather than
            # aborting mid-run at admission time
            bad = [r.rid for r in requests if worst_extent(r) > self.max_seq]
            if bad:
                raise ValueError(
                    f"requests {bad} need more cache than max_seq="
                    f"{self.max_seq} (bucketed prompt + max_new"
                    + (f" + the k-1 speculative overhang"
                       if self._spec_overhang else "")
                    + "); size the Server for the longest request")
        if self.paged:
            # same fail-fast for the block pool: a request whose worst case
            # exceeds the whole pool could never reserve, and admission
            # would head-of-line-block forever
            bad = [r.rid for r in requests
                   if kvc.blocks_for(worst_extent(r), self.block_size)
                   > ex.n_blocks]
            if bad:
                raise ValueError(
                    f"requests {bad} need more blocks than the pool holds "
                    f"(n_blocks={ex.n_blocks} of {self.block_size} "
                    f"positions); grow --cache-blocks or shrink the "
                    f"requests")
        if warmup:
            self.warmup(requests)
        blocks = None
        prefix = None
        if self.paged:
            blocks = BlockAllocator(ex.n_blocks, n_shards=ex.n_block_shards,
                                    shard_of=ex.block_shards)
            if self.prefix_enabled or self.slo is not None:
                # align hits to the prefill-bucket grid: the reservation /
                # fail-fast / table-width math bounds suffix coverage by
                # bucket(len(prompt)) only for bucket-aligned pos0.  SLO
                # mode needs the cache even with --prefix-cache off — it is
                # the swap layer preemption publishes into and restore
                # re-attaches from.
                prefix = PrefixCache(self.block_size,
                                     align_tokens=self.bucket)
        sched = Scheduler(requests, self.max_batch,
                          n_shards=ex.n_slot_shards, shard_of=ex.slot_shards,
                          blocks=blocks,
                          blocks_needed=(self._blocks_needed if blocks
                                         is not None else None),
                          prefix=prefix, policy=self.slo)
        cache = ex.init_cache()
        B = self.max_batch
        tok = np.zeros((B, 1), np.int32)
        pos = np.zeros((B, 1), np.int32)
        act = np.zeros((B,), bool)
        # remaining emission budget per slot — the multi-step round's
        # in-kernel retirement counter (mirrors Executor.decode_multi's
        # ``remaining`` input; unused state at horizon 1)
        rem = np.zeros((B,), np.int32)
        bt = (ex.make_block_table() if self.paged else None)
        chunking: Dict[int, int] = {}      # slot -> next piece offset
        steps = 0
        n_chunks = 0
        rounds = 0
        host_syncs = 0                     # host-blocking d2h syncs
        loop_iters = 0
        peak_running = 0
        M = self.decode_horizon
        multi = M > 1
        # Round pipelining (DESIGN.md §3 "Multi-step decode & host
        # overlap"): dispatch round N+1 from the DEVICE-resident carry
        # before the host processes round N's tokens, so scheduler work
        # overlaps device compute.  SLO preemption and chunked prefill
        # mutate host slot state at arbitrary boundaries, so those modes
        # drain every round immediately instead (still one sync per M
        # tokens — only the overlap is forgone).
        pipeline = multi and self.slo is None and not self.prefill_chunk
        pending = None        # in-flight round's (M, B) device tokens
        carry = None          # device carry chained round-to-round
        t0 = clock()

        def process_toks(toks_dev) -> None:
            """Sync one finished round and replay the device's retirement
            recurrence over the host mirrors: emit per-slot streams, retire
            EOS/budget-exhausted slots, and leave tok/pos/act/rem exactly
            equal to the device carry row-for-row (the identity argument in
            DESIGN.md — same recurrence, same state)."""
            nonlocal host_syncs
            toks = np.asarray(toks_dev)                  # (M, B) host sync
            host_syncs += 1
            now = clock() - t0
            emitted, act_out, rem_out = replay_round(toks, act, rem,
                                                     self.eos_id)
            for slot in list(sched.running):
                if not emitted[slot]:
                    continue       # not entry-active (free / chunking slot)
                req = sched.running[slot]
                for t in emitted[slot]:
                    req.emit(t, now)
                pos[slot, 0] += len(emitted[slot])
                tok[slot, 0] = emitted[slot][-1]
                rem[slot] = rem_out[slot]
                if not act_out[slot]:
                    act[slot] = False
                    sched.retire(slot, now)
                    if self.paged:
                        bt[slot, :] = -1

        def drain() -> None:
            """Process the in-flight round, if any.  MUST run before any
            host mutation of tok/pos/act/rem outside :func:`process_toks`
            (admission emit, chunk completion, preemption) — the mirrors
            lag the device by one round while a round is in flight, and
            mutating stale mirrors would fork the state."""
            nonlocal pending
            if pending is not None:
                prev, pending = pending, None
                process_toks(prev)

        def emit_first(slot: int, req: Request, first: int,
                       now: float) -> None:
            """Book a prefill's emitted token and arm the slot for decode
            (shared by fresh admission, final chunk, and restore — the
            feed position is uniformly the index of the newest token in
            ``full_seq``, whose KV the NEXT step writes)."""
            nonlocal carry
            carry = None       # host mutated: rebuild from mirrors
            req.emit(first, now)
            if first == self.eos_id or len(req.tokens) >= req.max_new:
                sched.retire(slot, now)
                if self.paged:
                    bt[slot, :] = -1
                return
            tok[slot, 0] = first
            pos[slot, 0] = len(req.prompt) + len(req.tokens) - 1
            act[slot] = True
            rem[slot] = req.max_new - len(req.tokens)

        def preempt_slot(vslot: int, vnow: float) -> None:
            """Evict a victim: publish only the KV actually written (a
            decode victim's pending token never was — ``pos`` is the feed
            position; a chunking victim has exactly ``[0, cur)``), clear
            the slot state, and re-queue it at its policy position."""
            nonlocal carry
            carry = None       # host mutated: rebuild from mirrors
            covered = chunking.pop(vslot, None)
            if covered is None:
                covered = int(pos[vslot, 0])
            sched.preempt(vslot, vnow, covered=covered)
            act[vslot] = False
            bt[vslot, :] = -1
            tok[vslot, 0] = 0
            pos[vslot, 0] = 0

        def secure_one(req: Request) -> bool:
            """Make one more block allocatable for ``req`` — the
            optimistic-reservation pressure path (DESIGN.md §3 "SLO
            scheduling"): spend remaining reservation if any; otherwise
            free capacity (LRU prefix eviction first — preempted victims'
            published blocks land there — then preempt the policy's
            preferred victim) and grow the reservation.  Returns False
            when ``req`` itself had to yield (no other victims left); the
            caller must skip the alloc.  Terminates: each round either
            evicts or preempts, at most max_batch preemptions are
            possible, and the per-request feasibility fail-fast means a
            lone runner always fits."""
            if sched.blocks.reserved_of(req.rid) > 0:
                return True
            while not sched.blocks.can_reserve(1):
                if prefix is not None:
                    prefix.evict_until(sched.blocks, 1)
                    if sched.blocks.can_reserve(1):
                        break
                victims = [s for s in sched.running if s != req.slot]
                if not victims:
                    preempt_slot(req.slot, clock() - t0)
                    return False
                v = max(victims,
                        key=lambda s: self.slo.victim_key(sched.running[s]))
                preempt_slot(v, clock() - t0)
            sched.blocks.grow_reserve(req.rid, 1)
            return True

        while not sched.done:
            loop_iters += 1
            now = clock() - t0
            sched.poll(now)
            if continuous or not sched.running:
                admits = sched.admit(now)
                if admits:
                    drain()      # mirrors must be current before emit_first
                    firsts, cache = self._prefill_admits(cache, admits,
                                                         sched, bt, chunking)
                    if any(f is not None for f in firsts):
                        host_syncs += 1
                    now = clock() - t0
                    peak_running = max(peak_running, len(sched.running))
                    for (slot, req), first in zip(admits, firsts):
                        if first is None:
                            continue     # chunking: nothing emitted yet
                        emit_first(slot, req, first, now)
            if chunking:
                # one piece per loop iteration (lowest slot first, for
                # determinism): a long prefill interleaves with decode
                # steps instead of stalling every running request
                slot = min(chunking)
                first, cache = self._advance_chunk(cache, slot, sched, bt,
                                                   chunking)
                n_chunks += 1
                if first is not None:
                    host_syncs += 1
                    emit_first(slot, sched.running[slot], first,
                               clock() - t0)
            if not sched.running:
                if sched.waiting:
                    continue   # slots free (instant retirements): re-admit
                nxt = sched.next_arrival_s()
                if nxt is None:
                    break                      # everything drained
                wait = nxt - (clock() - t0)
                if wait > 0:
                    # sleep the actual remaining gap (capped so a clock
                    # hiccup can't oversleep an arrival by much) — the old
                    # 5 ms slices busy-spun O(gap / 5ms) iterations per
                    # arrival gap on sparse traces
                    time.sleep(min(wait, 0.25))
                continue
            if not act.any():
                continue       # every running slot is still mid-chunking
            if self.paged:
                # alloc-on-demand: every block this step's writes can touch
                # must exist before the step runs.  FIFO mode reserved the
                # worst case at admission so the alloc cannot fail; the SLO
                # policy's optimistic reservation secures the shortfall
                # here (eviction, then preemption).  A plain step writes
                # one position; a speculative round writes k consecutive; a
                # multi-step round writes up to M — and with a round in
                # flight the device carry can already sit M ahead of the
                # host mirror, so the pipelined span doubles.  Positions
                # past the request's final feed (prompt + max_new - 2)
                # are never written, so the span is capped there and the
                # FIFO worst-case reservation still covers it.
                span = max(self.spec_k, M)
                if pipeline and pending is not None:
                    span += M
                for slot, req in list(sched.running.items()):
                    if not act[slot]:
                        continue        # chunking, or preempted just now
                    p0 = int(pos[slot, 0])
                    hi = min(p0 + span - 1,
                             len(req.prompt) + req.max_new - 2)
                    for li in range(p0 // self.block_size,
                                    hi // self.block_size + 1):
                        if bt[slot, li] < 0:
                            if (self.slo is not None
                                    and not secure_one(req)):
                                break   # req itself yielded its slot
                            bt[slot, li] = sched.blocks.alloc(
                                req.rid, shard=self._block_pref(slot))
                if not act.any():
                    continue   # pressure path preempted every decoder
            if self.spec:
                cache = self._spec_round(sched, cache, tok, pos, act, bt,
                                         lambda: clock() - t0)
                host_syncs += 2          # draft + verdict materializations
                steps += 1
                continue
            if multi:
                # one horizon-M round: chained from the device carry when
                # the host hasn't touched its mirrors since the last round
                # (zero carry upload in steady state), rebuilt from the
                # mirrors otherwise
                src = carry if carry is not None else {
                    "token": tok, "pos": pos, "active": act,
                    "remaining": rem}
                toks_dev, carry, cache = ex.decode_multi(
                    src["token"], src["pos"], src["active"],
                    src["remaining"], cache, block_table=bt,
                    eos_id=self.eos_id)
                steps += M
                rounds += 1
                prev, pending = pending, toks_dev
                if prev is not None:
                    # double buffer: the device is already running round
                    # N+1 while the host replays round N here
                    process_toks(prev)
                if not pipeline:
                    drain()
                continue
            new_tok, cache = ex.decode(tok, pos, act, cache, block_table=bt)
            new_tok = np.asarray(new_tok)
            host_syncs += 1
            steps += 1
            now = clock() - t0
            for slot in list(sched.running):
                if not act[slot]:
                    continue            # chunking slot: masked this step
                req = sched.running[slot]
                t = int(new_tok[slot])
                req.emit(t, now)
                pos[slot, 0] += 1
                if t == self.eos_id or len(req.tokens) >= req.max_new:
                    act[slot] = False
                    sched.retire(slot, now)
                    if self.paged:
                        bt[slot, :] = -1
                else:
                    tok[slot, 0] = t
        drain()      # a trailing all-masked round can still be in flight
        wall = clock() - t0
        stats = summarize(sched.finished, wall,
                          mode="continuous" if continuous else "static")
        stats["decode_steps"] = steps
        stats["decode_compiles"] = self.decode_cache_size()
        stats["slot_shards"] = ex.n_slot_shards
        stats["cache_layout"] = "paged" if self.paged else "dense"
        stats["cache_bytes"] = self.cache_bytes
        stats["peak_concurrency"] = peak_running
        # Host-overlap accounting (DESIGN.md §3 "Multi-step decode & host
        # overlap"): every host-BLOCKING device->host materialization the
        # loop paid (decode steps / multi-step rounds / spec draft+verdict
        # pairs / prefill first-token reads).  The per-token ratio is the
        # serve_bench §7 gate: horizon M cuts it ~Mx.
        stats["host_syncs"] = host_syncs
        stats["host_syncs_per_token"] = round(
            host_syncs / max(stats["tokens"], 1), 4)
        stats["loop_iters"] = loop_iters
        stats["decode_horizon"] = self.decode_horizon
        if multi:
            stats["decode_rounds"] = rounds
        # MFU / tokens-per-joule (the paper's MACs/W figure of merit tied
        # back to measured throughput; ROADMAP).  MACs/token comes from the
        # analytic roofline at the mean final context; peak is the
        # roofline's per-chip constant times the mesh size; energy is the
        # --watts CLI stand-in (board power), so tokens/J = tok/s / W.
        fin = sched.finished
        mean_ctx = (sum(len(r.full_seq) for r in fin) / len(fin)
                    if fin else 1.0)
        macs_tok = decode_macs_per_token(self.cfg, int(mean_ctx))
        n_dev = int(ex.mesh.size)
        stats["macs_per_token"] = round(macs_tok, 1)
        stats["mfu"] = round(
            2.0 * macs_tok * stats["tok_per_s"] / (PEAK_FLOPS * n_dev), 8)
        stats["watts"] = self.watts
        stats["tokens_per_joule"] = round(
            stats["tok_per_s"] / self.watts, 4) if self.watts > 0 else 0.0
        if self.spec:
            rounds = int(sum(r.spec_rounds for r in sched.finished))
            accepted = int(sum(r.spec_accepted for r in sched.finished))
            stats["speculative"] = {
                "draft_bits": self.spec[0],
                "k": self.spec[1],
                "rounds": rounds,
                "accepted_draft_tokens": accepted,
                "mean_accepted": (round(accepted / rounds, 3)
                                  if rounds else 0.0),
                "spec_compiles": ex.spec_cache_sizes(),
            }
        # prefill accounting: the per-request counter, not len(prompt) -
        # hits — chunked pieces, preemption restores, and cumulative
        # re-admission hits all move the real forwarded count away from
        # that difference (which can even go negative once hit accounting
        # is cumulative across re-admissions)
        n_done = max(len(sched.finished), 1)
        prefilled = int(sum(r.prefilled_tokens for r in sched.finished))
        stats["prefilled_tokens"] = prefilled
        stats["prefilled_tokens_mean"] = round(prefilled / n_done, 2)
        stats["prefix_tokens_reused"] = int(sum(r.prefix_hit_tokens
                                                for r in sched.finished))
        if self.prefill_chunk:
            stats["prefill_chunks"] = n_chunks
        if self.slo is not None:
            stats["slo"] = {
                "aging_s": self.slo.aging_s,
                "reserve_frac": self.slo.reserve_frac,
                "classes": slo_report(sched.finished, self.slo),
            }
        if self.paged:
            stats["block_size"] = self.block_size
            stats["n_blocks"] = ex.n_blocks
            stats["paged_attn_route"] = ex.paged_attn_route
            # DeviceBlockTable transfer accounting: reuses are dispatches
            # that moved ZERO table bytes host->device
            stats["block_table_transfers"] = dict(bt.stats)
            stats["peak_blocks_in_use"] = blocks.high_watermark
            stats["block_util_pct"] = round(
                100.0 * blocks.high_watermark / max(ex.n_blocks, 1), 1)
            if prefix is not None:
                stats["prefix_cache"] = prefix.stats()
                # teardown: with refcounts, "allocator back to initial"
                # includes draining the LRU — after this, blocks_free_end
                # must equal n_blocks again (leak check in tests)
                prefix.drain(blocks)
            stats["blocks_free_end"] = blocks.free_count
        return sched.finished, stats

    def decode_cache_size(self) -> int:
        """Compiled decode-side executable count for the engine's ACTIVE
        decode path: the horizon-M round when multi-step decode is on
        (the single-step twin is never traced then — warmup asserts it),
        else the classic single step."""
        if self.decode_horizon > 1:
            return self.executor.decode_multi_cache_size()
        return self.executor.decode_cache_size()


def build_server(args) -> Tuple[Server, object]:
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    cfg = dataclasses.replace(
        cfg,
        cache_layout=getattr(args, "cache_layout", "auto") or "auto",
        cache_block_size=int(getattr(args, "block_size", 0)
                             or cfg.cache_block_size),
        prefix_cache=(getattr(args, "prefix_cache", "off") == "on"))
    cfg.resolved_cache_layout        # validate the layout/family combo early
    cfg.prefix_cache_enabled         # ...and the prefix-cache combo
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    pre = int(getattr(args, "qat_precondition", 0) or 0)
    if pre:
        # Emulate a checkpoint TRAINED with the quantizer in the loop (the
        # paper's QAT flow): snap weights to the psi`pre` grid before the
        # serving quantization.  Random-init weights have logit margins
        # smaller than low-bit quantization noise, so without this the
        # speculative draft's acceptance rate is ~0 — a trained checkpoint's
        # margins are what make self-speculation pay (DESIGN.md).
        params = fake_quant_param_tree(params, pre)
    policy = parse_policy(getattr(args, "quant_policy", None))
    if args.quant != "none" or policy:
        _, bits = parse_quant_mode(args.quant)
        # pack=True only bit-plane-packs sub-byte leaves, so uniform psi8
        # stays plain int8 codes while psi5/psi4/... leaves shrink to
        # fmt.bits/8 bytes per weight.
        params = model.quantize(params, bits, pack=True, policy=policy)
        # quant_mode drives the float-leaf (QAT) path only; for serving it
        # records the uniform format (or the policy default) for logging.
        mode = args.quant
        if mode == "none" and policy and policy.get("default"):
            mode = f"psi{policy['default']}"
        cfg = dataclasses.replace(cfg, quant_mode=mode)
    spec = parse_spec_spec(getattr(args, "speculative", None))
    if spec:
        kind, sbits = parse_quant_mode(args.quant)
        if kind != "psi" or sbits <= spec[0]:
            raise ValueError(
                f"--speculative {spec[0]}:{spec[1]} derives the draft from "
                f"the PSI serving codes, so it needs a WIDER serving format "
                f"(--quant psiN with N > {spec[0]}); got --quant "
                f"{args.quant}")
    # Cache extent must cover the *bucketed* prefill plus the decode budget,
    # or the ring layout would silently drop the prompt head.  A shared
    # system prompt prepends to every request's unique tail.  Speculative
    # rounds write k positions regardless of remaining budget: +k-1.
    longest = (args.prompt_len + args.prompt_jitter
               + getattr(args, "shared_prefix_len", 0))
    prompt_pad = -(-longest // PREFILL_BUCKET) * PREFILL_BUCKET
    mesh = parse_mesh_spec(getattr(args, "mesh", None))
    # Round the cache extent to the block grid for EVERY layout: a paged
    # Server rounds anyway, and giving dense the same extent keeps the two
    # layouts' attention shapes — and therefore their greedy tokens —
    # bit-identical for the serve_bench cross-layout assertion.
    max_seq = prompt_pad + args.max_new + 8 + (spec[1] - 1 if spec else 0)
    chunk = int(getattr(args, "prefill_chunk", 0) or 0)
    slo = parse_slo_spec(getattr(args, "slo", "off") or "off")
    if slo is not None or chunk:
        # restore headroom: a preempted request re-prefills prompt +
        # generated in one bucketed piece, whose padded extent can exceed
        # the prompt-only pad by up to one bucket
        max_seq += PREFILL_BUCKET
    bsz = cfg.cache_block_size
    max_seq = -(-max_seq // bsz) * bsz
    server = Server(cfg, params, max_batch=args.max_batch, max_seq=max_seq,
                    eos_id=args.eos_id, mesh=mesh,
                    n_blocks=getattr(args, "cache_blocks", None),
                    speculative=spec, prefill_chunk=chunk, slo=slo,
                    decode_horizon=int(getattr(args, "decode_horizon", 1)
                                       or 1),
                    watts=float(getattr(args, "watts", 215.0)))
    return server, cfg


def trace_from_args(args, cfg):
    """One arrival trace from the shared CLI flags (used by both the serve
    CLI and benchmarks/serve_bench so the two can never drift).
    ``--trace-seed`` decouples the arrival RNG from ``--seed`` (which also
    fixes the weights) so traffic can vary against a fixed checkpoint;
    ``--priority-mix`` draws each request's SLO class from the --slo
    policy's classes with the given weights."""
    seed = getattr(args, "trace_seed", None)
    if seed is None:
        seed = args.seed
    mix = None
    pm = getattr(args, "priority_mix", None)
    if pm:
        slo = parse_slo_spec(getattr(args, "slo", "off") or "off")
        if slo is None:
            raise ValueError("--priority-mix draws classes from the --slo "
                             "policy; pass --slo as well")
        mix = slo.mix([float(x) for x in pm.split(",")])
    return poisson_trace(args.requests, rate_rps=args.arrival_rate,
                         prompt_len=args.prompt_len,
                         max_new=args.max_new, min_new=args.min_new,
                         prompt_jitter=args.prompt_jitter,
                         shared_prefix_len=getattr(args, "shared_prefix_len",
                                                   0),
                         vocab_size=cfg.vocab_size, seed=int(seed),
                         priority_mix=mix)


def _positive_rate(s: str) -> float:
    """--arrival-rate parser: the trace generator divides by the rate, so 0
    is a ZeroDivisionError waiting to happen and a negative rate would run
    time backwards — reject both at the CLI boundary."""
    v = float(s)
    if not v > 0:
        raise argparse.ArgumentTypeError(
            f"--arrival-rate must be > 0 requests/s, got {s!r}")
    return v


def add_serve_args(ap: argparse.ArgumentParser) -> None:
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--quant", default="psi8",
                    choices=list(serving_mode_choices()),
                    help="uniform PSI serving width (any registered "
                         "PsiFormat; sub-byte widths bit-plane pack)")
    ap.add_argument("--quant-policy", default=None,
                    help='per-layer mixed precision, e.g. '
                         '"embed=8,w_down=4,default=5" — names match '
                         'terminal weight leaves, "default" covers the '
                         'rest, 0 keeps a leaf in float.  Overrides '
                         '--quant where it matches.')
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--max-batch", type=int, default=4,
                    help="decode slots (the fixed decode batch dimension)")
    ap.add_argument("--arrival-rate", type=_positive_rate, default=1000.0,
                    help="Poisson arrival rate, requests/s, > 0 (the "
                         "reduced CPU model decodes ~3k tok/s, so this "
                         "saturates it)")
    ap.add_argument("--max-new", type=int, default=48,
                    help="per-request decode budgets are drawn from "
                         "[min-new, max-new]")
    ap.add_argument("--min-new", type=int, default=1)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--prompt-jitter", type=int, default=0,
                    help="+- this many tokens of per-request prompt-length "
                         "variation (exercises heterogeneous admission)")
    ap.add_argument("--cache-layout", default="auto",
                    choices=["auto", "dense", "paged"],
                    help="decode-cache layout (DESIGN.md §3): paged = block "
                         "pool + per-slot block tables (default for "
                         "full-attention families); dense = per-slot slabs "
                         "(required for SSM/hybrid/SWA/encdec state)")
    ap.add_argument("--block-size", type=int, default=0,
                    help="positions per paged cache block (0 = config "
                         "default, 16)")
    ap.add_argument("--cache-blocks", type=int, default=None,
                    help="usable pool blocks for --cache-layout paged "
                         "(default: dense-equivalent capacity, "
                         "max_batch * ceil(max_seq / block_size); smaller "
                         "values trade capacity for memory and gate "
                         "admission on block availability)")
    ap.add_argument("--prefix-cache", default="off", choices=["on", "off"],
                    help="shared-prefix block reuse over the paged pool "
                         "(DESIGN.md §3): admission serves the longest "
                         "cached block-aligned prompt prefix out of "
                         "ref-counted blocks and prefills only the suffix. "
                         "Requires --cache-layout paged (the full-attention "
                         "default).")
    ap.add_argument("--shared-prefix-len", type=int, default=0,
                    help="prepend ONE fixed random prefix of this many "
                         "tokens to every prompt (the shared-system-prompt "
                         "traffic shape; --prompt-len then sizes the "
                         "unique tail)")
    ap.add_argument("--speculative", default=None, metavar="BITS:K",
                    help="self-speculative decoding (DESIGN.md): draft K "
                         "tokens per round with a psiBITS view of the "
                         "serving checkpoint (derived from the stored "
                         "codes — no second model), then verify all K in "
                         "one target-width pass; greedy acceptance keeps "
                         "outputs token-identical to plain decode.  e.g. "
                         "\"3:4\".  Requires --quant psiN with N > BITS, "
                         "the paged cache layout, and K <= --block-size.")
    ap.add_argument("--qat-precondition", type=int, default=0, metavar="BITS",
                    help="snap the random-init weights to the psiBITS grid "
                         "before serving quantization (emulates a QAT-"
                         "trained checkpoint; 0 = off).  Random weights' "
                         "logit margins drown in low-bit noise, so "
                         "speculative acceptance studies need this.")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="split prompt prefills into pieces of this many "
                         "tokens, interleaved with decode steps so a long "
                         "admission stops stalling running requests (0 = "
                         "off; rounded UP to lcm(block-size, prefill "
                         "bucket)).  Requires the paged layout + plain "
                         "RoPE; tokens stay identical to unchunked serving.")
    ap.add_argument("--slo", default="off",
                    help='SLO scheduling (DESIGN.md §3 "SLO scheduling"): '
                         '"off", "default" (interactive/standard/batch), '
                         'or "name:prio:ttft:itl,..." custom classes; '
                         'append "@aging=S" / "@reserve=F" knobs.  Turns '
                         'on aged-priority admission, optimistic block '
                         'reservation, and preemption with prefix-cache-'
                         'backed restore.  Requires the paged layout + '
                         'plain RoPE.')
    ap.add_argument("--trace-seed", type=int, default=None,
                    help="RNG seed for the arrival trace only (default: "
                         "--seed), so traffic varies against fixed weights")
    ap.add_argument("--priority-mix", default=None, metavar="W1,W2,...",
                    help="per-class arrival weights, one per --slo class "
                         "in declaration order; each request draws its "
                         "class i.i.d. from the normalized mix")
    ap.add_argument("--decode-horizon", type=int, default=1, metavar="M",
                    help='multi-step decode (DESIGN.md §3 "Multi-step '
                         'decode & host overlap"): fuse M decode steps '
                         'into ONE on-device round (lax.scan) with EOS/'
                         'max-new retirement masked in-kernel, and let the '
                         'host process each round\'s tokens while the '
                         'device runs the next — ~Mx fewer host syncs per '
                         'token, bit-token-identical to M=1.  Does not '
                         'compose with --speculative (hard error).')
    ap.add_argument("--watts", type=float, default=215.0,
                    help="board-power stand-in for the tokens-per-joule "
                         "stat (default: a TPU v5e-class figure, matching "
                         "the roofline peak-FLOPs denominator)")
    ap.add_argument("--eos-id", type=int, default=-1,
                    help="-1 disables EOS retirement")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mesh", default=None,
                    help='serving mesh "DATAxMODEL" (e.g. 4x2); decode '
                         'slots partition over the data axis, weights TP '
                         'over model.  Default/1x1: single-device path')


def main():
    ap = argparse.ArgumentParser()
    add_serve_args(ap)
    ap.add_argument("--mode", default="continuous",
                    choices=["continuous", "static", "both"])
    args = ap.parse_args()

    server, cfg = build_server(args)
    modes = (["continuous", "static"] if args.mode == "both"
             else [args.mode])
    for mode in modes:
        trace = trace_from_args(args, cfg)
        done, stats = server.serve(trace, continuous=(mode == "continuous"))
        cache_info = f"cache {stats['cache_layout']}"
        if stats["cache_layout"] == "paged":
            cache_info += (f" ({stats['n_blocks']}x{stats['block_size']} "
                           f"blocks, {stats['paged_attn_route']} read, "
                           f"peak util {stats['block_util_pct']}%)")
        if "prefix_cache" in stats:
            pc = stats["prefix_cache"]
            cache_info += (f" | prefix hit rate {pc['hit_rate']:.2f}, "
                           f"{stats['prefix_tokens_reused']} tok reused / "
                           f"{stats['prefilled_tokens']} prefilled")
        if "speculative" in stats:
            sp = stats["speculative"]
            cache_info += (f" | spec psi{sp['draft_bits']} k={sp['k']}: "
                           f"{stats['accepted_per_step']:.2f} accepted/"
                           f"round, draft {stats['draft_overhead_s']:.3f}s")
        if stats.get("preemptions") or "slo" in stats:
            cache_info += (f" | preemptions {stats['preemptions']}, "
                           f"restores "
                           f"{stats.get('prefix_cache', {}).get('restores', 0)}")
        if stats["decode_horizon"] > 1:
            cache_info += (f" | horizon {stats['decode_horizon']}: "
                           f"{stats['decode_rounds']} rounds, "
                           f"{stats['host_syncs_per_token']:.3f} syncs/tok")
        print(f"[{mode}] served {stats['n_requests']} requests: "
              f"{stats['tokens']} tokens in {stats['wall_s']:.3f}s = "
              f"{stats['tok_per_s']:.1f} tok/s | "
              f"mfu {stats['mfu']:.2e} | "
              f"{stats['tokens_per_joule']:.2f} tok/J @ {stats['watts']:.0f}W | "
              f"latency p50 {stats['p50_latency_s'] * 1e3:.0f}ms "
              f"p99 {stats['p99_latency_s'] * 1e3:.0f}ms | "
              f"ttft p50 {stats['p50_ttft_s'] * 1e3:.0f}ms | "
              f"decode compiles {stats['decode_compiles']} | "
              f"slot shards {stats['slot_shards']} | {cache_info}")
        if "slo" in stats:
            for name, c in stats["slo"]["classes"].items():
                print(f"  [{name}] n={c['n_requests']} "
                      f"ttft p99 {c['p99_ttft_s'] * 1e3:.0f}ms "
                      f"(attain {c['ttft_attainment']:.2f} of "
                      f"{c['ttft_deadline_s'] * 1e3:.0f}ms) | "
                      f"itl p99 {c['p99_itl_s'] * 1e3:.0f}ms "
                      f"(attain {c['itl_attainment']:.2f}) | "
                      f"preemptions {c['preemptions']}")
        for r in done[:2]:
            print(f"  req {r.rid}: slot {r.slot}, {len(r.tokens)} tokens, "
                  f"{r.out[:10].tolist()}...")


if __name__ == "__main__":
    main()
