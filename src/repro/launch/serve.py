"""Batched serving driver: continuous-batching loop over PSI-quantized
weights (the paper's inference regime, scaled to LM decode).

Requests arrive with prompts; the scheduler packs up to ``max_batch`` active
sequences, prefills new arrivals, and decodes the active set step by step,
retiring sequences at EOS/limit.  The decode step runs entirely on the PSI
serving format — on TPU the psi_matmul Pallas kernel reads 5/8-bit weights
from HBM (DESIGN.md §2).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b --reduced \
      --quant psi8 --requests 6 --max-new 16
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced_config
from repro.data.pipeline import make_batch_for
from repro.models import build_model


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray              # (S,) int32
    max_new: int
    out: Optional[np.ndarray] = None
    latency_s: float = 0.0


class Server:
    """Static-batch serving engine (prefill + decode loop)."""

    def __init__(self, cfg, params, max_seq: int = 256):
        self.cfg = cfg
        self.model = build_model(cfg)
        self.params = params
        self.max_seq = max_seq
        self._decode = jax.jit(self.model.decode_step)
        self._prefill = jax.jit(
            lambda p, b: self.model.prefill(p, b, cache_len=max_seq))

    def run_batch(self, requests: List[Request], greedy: bool = True):
        cfg = self.cfg
        B = len(requests)
        S = max(len(r.prompt) for r in requests)
        toks = np.zeros((B, S), np.int32)
        for i, r in enumerate(requests):          # left-pad-free simple pack
            toks[i, :len(r.prompt)] = r.prompt
        batch = make_batch_for(cfg, B, S, jax.random.PRNGKey(0))
        batch["tokens"] = jnp.asarray(toks)
        t0 = time.time()
        logits, cache = self._prefill(self.params, batch)
        new_tokens = [[] for _ in range(B)]
        cur = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        max_new = max(r.max_new for r in requests)
        for step in range(max_new):
            pos = jnp.full((B, 1), S + step, jnp.int32)
            db = {"token": cur, "pos": pos}
            if cfg.rope == "mrope":
                db["positions"] = jnp.broadcast_to(pos[:, None, :], (B, 3, 1))
            logits, cache = self._decode(self.params, db, cache)
            for i in range(B):
                if step < requests[i].max_new:
                    new_tokens[i].append(int(cur[i, 0]))
            cur = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        dt = time.time() - t0
        for i, r in enumerate(requests):
            r.out = np.asarray(new_tokens[i], np.int32)
            r.latency_s = dt
        return requests, {"batch": B, "prefill_len": S,
                          "decode_steps": max_new, "wall_s": dt,
                          "tok_per_s": B * max_new / dt}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--quant", default="psi8",
                    choices=["none", "psi5", "psi8"])
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=32)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    if args.quant != "none":
        bits = int(args.quant[-1])
        params = model.quantize(params, bits, pack=(bits == 5))
        cfg = dataclasses.replace(cfg, quant_mode=args.quant)
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(0, cfg.vocab_size,
                                    size=(args.prompt_len,)).astype(np.int32),
                    args.max_new)
            for i in range(args.requests)]
    server = Server(cfg, params,
                    max_seq=args.prompt_len + args.max_new + 8)
    done, stats = server.run_batch(reqs)
    print(f"served {len(done)} requests: {stats}")
    for r in done[:2]:
        print(f"  req {r.rid}: {r.out[:12]}...")


if __name__ == "__main__":
    main()
