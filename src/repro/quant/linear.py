"""PsiLinear — the single matmul entry point used by every model layer.

Three code paths, selected by the weight leaf's *type* and the config's
``quant_mode``:

* plain float leaf, mode "none"            -> bf16 einsum (MXU, f32 accum)
* plain float leaf, mode "qatN"            -> fake-quant STE then einsum
  (the paper's "trained with the proposed quantization")
* ``QuantizedTensor`` leaf                 -> PSI kernel, dispatched on the
  leaf's ``PsiFormat`` + storage layout (``repro.kernels.ops``: Pallas on
  TPU, oracle on CPU)

Keeping one entry point means every architecture in the zoo gets the paper's
technique for free — including per-layer mixed precision, because each leaf
carries its own format — and the dry-run's HBM byte counts reflect the
compressed weight format.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import psi, quantizer
from repro.kernels import ops


def _maybe_fake_quant(w: jnp.ndarray, quant_mode: str, axis) -> jnp.ndarray:
    kind, bits = quantizer.parse_quant_mode(quant_mode)
    if kind != "qat":
        return w
    return psi.fake_quant_ste(w, bits, axis)


def linear(wleaf, x: jnp.ndarray, quant_mode: str = "none") -> jnp.ndarray:
    """x (..., K) @ w (K, N) -> (..., N)."""
    if isinstance(wleaf, psi.QuantizedTensor):    # PSI serving format
        return ops.psi_matmul(x, wleaf)
    w = _maybe_fake_quant(wleaf, quant_mode, axis=(wleaf.ndim - 2,))
    y = jnp.einsum("...k,kn->...n", x, w.astype(x.dtype),
                   preferred_element_type=jnp.float32)
    return y.astype(x.dtype)


def embed(wleaf, ids: jnp.ndarray, dtype=jnp.bfloat16) -> jnp.ndarray:
    """Embedding lookup; PSI tables dequantize per gathered row.

    Packed (bit-plane) tables unpack only the gathered rows — the shared
    ``QuantizedTensor.gather_rows`` path — so a ``--pack`` embedding leaf
    serves instead of raising on a missing "codes" key.
    """
    if isinstance(wleaf, psi.QuantizedTensor):
        return wleaf.gather_rows(ids, dtype)
    return wleaf[ids].astype(dtype)


def tied_logits(wleaf, x: jnp.ndarray, quant_mode: str = "none") -> jnp.ndarray:
    """logits = x @ embed_table.T with per-row (= per-vocab-output) scales."""
    if isinstance(wleaf, psi.QuantizedTensor):
        codes_t = wleaf.codes.T                   # (D, V); unpacks if packed
        return ops.psi_matmul(x, psi.QuantizedTensor(
            codes_t, wleaf.scale.reshape(-1), wleaf.fmt))
    w = _maybe_fake_quant(wleaf, quant_mode, axis=(wleaf.ndim - 1,))
    y = jnp.einsum("...d,vd->...v", x, w.astype(x.dtype),
                   preferred_element_type=jnp.float32)
    return y.astype(x.dtype)
