"""PsiLinear — the single matmul entry point used by every model layer.

Three code paths, selected by the weight leaf's *type* and the config's
``quant_mode``:

* plain float leaf, mode "none"            -> bf16 einsum (MXU, f32 accum)
* plain float leaf, mode "qat5"/"qat8"     -> fake-quant STE then einsum
  (the paper's "trained with the proposed quantization")
* serving dict leaf ({"codes"|"planes", "scale"}) -> PSI kernel
  (``repro.kernels.ops``: Pallas on TPU, oracle on CPU)

Keeping one entry point means every architecture in the zoo gets the paper's
technique for free, and the dry-run's HBM byte counts reflect the compressed
weight format.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import psi
from repro.kernels import ops

_QAT_BITS = {"qat5": 5, "qat8": 8}


def _maybe_fake_quant(w: jnp.ndarray, quant_mode: str, axis) -> jnp.ndarray:
    bits = _QAT_BITS.get(quant_mode)
    if bits is None:
        return w
    return psi.fake_quant_ste(w, bits, axis)


def linear(wleaf, x: jnp.ndarray, quant_mode: str = "none") -> jnp.ndarray:
    """x (..., K) @ w (K, N) -> (..., N)."""
    if isinstance(wleaf, dict):                      # PSI serving format
        return ops.psi_matmul(x, wleaf)
    w = _maybe_fake_quant(wleaf, quant_mode, axis=(wleaf.ndim - 2,))
    y = jnp.einsum("...k,kn->...n", x, w.astype(x.dtype),
                   preferred_element_type=jnp.float32)
    return y.astype(x.dtype)


def embed(wleaf, ids: jnp.ndarray, dtype=jnp.bfloat16) -> jnp.ndarray:
    """Embedding lookup; PSI tables dequantize per gathered row."""
    if isinstance(wleaf, dict):
        codes = wleaf["codes"]                       # (V, D) int8
        rows = codes[ids].astype(jnp.float32) * wleaf["scale"][ids]
        return rows.astype(dtype)
    return wleaf[ids].astype(dtype)


def tied_logits(wleaf, x: jnp.ndarray, quant_mode: str = "none") -> jnp.ndarray:
    """logits = x @ embed_table.T with per-row (= per-vocab-output) scales."""
    if isinstance(wleaf, dict):
        codes_t = wleaf["codes"].T                   # (D, V)
        return ops.psi_matmul(x, {"codes": codes_t,
                                  "scale": wleaf["scale"].reshape(-1)})
    w = _maybe_fake_quant(wleaf, quant_mode, axis=(wleaf.ndim - 1,))
    y = jnp.einsum("...d,vd->...v", x, w.astype(x.dtype),
                   preferred_element_type=jnp.float32)
    return y.astype(x.dtype)
