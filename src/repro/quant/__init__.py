from repro.quant.linear import linear, embed, tied_logits  # noqa: F401
# Re-exported typed quantization API (the first-class serving-format surface).
from repro.core.psi import PsiFormat, QuantizedTensor, get_format  # noqa: F401
from repro.core.quantizer import (dequantize, parse_policy,  # noqa: F401
                                  parse_quant_mode, quantize_param_tree)
