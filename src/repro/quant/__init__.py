from repro.quant.linear import linear, embed, tied_logits  # noqa: F401
