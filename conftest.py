"""Pytest bootstrap: a deterministic fallback for ``hypothesis``.

The property tests (test_psi / test_substrate / test_attention_units /
test_serving) use hypothesis when it is installed (see requirements-dev.txt).
Some execution environments — including the hermetic container the tier-1
suite runs in — cannot pip-install dev dependencies, and an absent
``hypothesis`` used to kill the whole suite at collection.  This shim
registers a minimal, deterministic stand-in implementing exactly the API the
tests use (``given``, ``settings``, ``strategies.integers``,
``strategies.lists``, ``strategies.sampled_from``): each property runs over
the strategy's boundary values
followed by seeded-random samples, so the suite stays meaningful (if less
adversarial than real hypothesis shrinking) and fully reproducible.
"""
import functools
import inspect
import random
import sys
import types


def _install_hypothesis_fallback():
    class _Strategy:
        """A sample generator: ``example(i, rng)`` yields boundary values for
        small ``i`` and seeded-random values afterwards."""

        def __init__(self, boundary, sample):
            self._boundary = boundary      # list of deterministic examples
            self._sample = sample          # fn(rng) -> value

        def example(self, i, rng):
            if i < len(self._boundary):
                return self._boundary[i]
            return self._sample(rng)

    def integers(min_value, max_value):
        bound = [min_value, max_value]
        if min_value < 0 < max_value:
            bound.append(0)
        return _Strategy(bound,
                         lambda rng: rng.randint(min_value, max_value))

    def sampled_from(values):
        values = list(values)
        return _Strategy(values, lambda rng: rng.choice(values))

    def lists(elements, min_size=0, max_size=10):
        def sample(rng):
            n = rng.randint(min_size, max_size)
            return [elements.example(2 + i, rng) for i in range(n)]

        bound = [[elements.example(0, random.Random(0))] * max(min_size, 1)]
        if min_size == 0:
            bound.insert(0, [])
        return _Strategy(bound, sample)

    def given(*strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(fn, "_fallback_max_examples", 50)
                rng = random.Random(0xC0FFEE)
                for i in range(n):
                    fn(*args, *(s.example(i, rng) for s in strategies),
                       **kwargs)
            # Hide the strategy-filled parameters from pytest's fixture
            # resolution (real hypothesis rewrites the signature too).
            sig = inspect.signature(fn)
            params = list(sig.parameters.values())[:-len(strategies)]
            wrapper.__signature__ = sig.replace(parameters=params)
            del wrapper.__wrapped__
            return wrapper
        return deco

    def settings(max_examples=100, deadline=None, **_kw):
        def deco(fn):
            fn._fallback_max_examples = max_examples
            return fn
        return deco

    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    st_mod = types.ModuleType("hypothesis.strategies")
    st_mod.integers = integers
    st_mod.lists = lists
    st_mod.sampled_from = sampled_from
    mod.strategies = st_mod
    mod.__is_repro_fallback__ = True
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st_mod


try:
    import hypothesis  # noqa: F401  (real package wins when available)
except ImportError:
    _install_hypothesis_fallback()
